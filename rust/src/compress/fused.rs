//! Fused integer-domain hot path shared by the all-reduce-compatible
//! aggregators (QSGD-MN, QSGD-MN-TS, GRandK variants).
//!
//! The pre-integer pipeline carried quantizer levels as `f32`: 32 bits per
//! coordinate through encode, the ring all-reduce, and decode — for a
//! nominally 2–16-bit wire format. Exactly the gap ScaleCom (Chen et al.,
//! 2020) identifies between paper speedups and deployed speedups. The
//! production path ([`qsgd_step_packed`] / [`multiscale_step_packed`]) now
//! encodes biased codes straight into a **packed resident operand** and
//! reduces it through the schedule-generic packed data plane
//! ([`crate::collectives::PackedReduce`]: fixed- or growing-width ring,
//! tree, naive — resolved per step from the net config and width policy),
//! decoding once from the exact integer sum. The widened-integer plane
//! ([`LevelInt`]: `i16` when `workers * s` fits, `i32` otherwise — the
//! overflow-safe widening rule; [`qsgd_step_int`] / [`multiscale_step_int`])
//! is kept as the property-pinned reference the packed plane must match bit
//! for bit. Encode fan-out runs on the persistent [`threads::pool`] instead
//! of spawning OS threads per step, and every buffer lives in the
//! aggregator across steps.
//!
//! [`wire_roundtrip_qsgd`] additionally pushes each worker's levels through
//! the packed wire format (`bitpack`) before reducing — the property tests
//! use it to pin the full encode→pack→allreduce→unpack→decode chain
//! bit-identical to the legacy f32 path ([`reference_qsgd_aggregate`]).

use crate::collectives::{self, StepCtx};
use crate::tensor::{sum_fits, LevelInt};
use crate::util::rng::Rng;
use crate::util::threads;

use super::bitpack;
use super::kernels::{self, ScaleTable};

/// Hard cap on simulated workers for the integer-domain aggregators. The
/// constructors assert `MAX_WORKERS * s <= i32::MAX`, making overflow
/// impossible by construction anywhere below this bound (for b <= 16,
/// `s <= 32767`, so `4096 * s <= 1.35e8` — two orders under `i32::MAX`).
pub const MAX_WORKERS: usize = 4096;

/// Construction-time overflow proof for a quantizer with `s` levels.
pub fn assert_widening_rule(s: usize) -> anyhow::Result<()> {
    anyhow::ensure!(
        sum_fits::<i32>(s, MAX_WORKERS),
        "widening rule violated: {MAX_WORKERS} workers x s={s} overflows i32"
    );
    Ok(())
}

/// Does the narrow (i16) accumulator suffice for this step?
pub fn narrow_fits(s: usize, workers: usize) -> bool {
    sum_fits::<i16>(s, workers)
}

/// Parallel per-worker QSGD encode into reusable integer scratch. Worker
/// streams derive from `rng` exactly like the legacy path (`derive([w])`),
/// so outputs are bit-identical given the same step rng.
pub fn encode_qsgd_into<T: LevelInt>(
    grads: &[&[f32]],
    wnorm: f32,
    s: usize,
    scratch: &mut Vec<Vec<T>>,
    uniform: &mut Vec<Vec<f32>>,
    rng: &Rng,
) {
    let m = grads.len();
    let n = grads[0].len();
    scratch.resize_with(m, Vec::new);
    uniform.resize_with(m, Vec::new);
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(m);
    for (w, ((buf, uni), g)) in scratch.iter_mut().zip(uniform.iter_mut()).zip(grads).enumerate() {
        let mut wrng = rng.derive(&[w as u64]);
        tasks.push(Box::new(move || {
            buf.resize(n, T::default());
            uni.resize(n, 0.0);
            wrng.fill_uniform_f32(uni);
            kernels::qsgd_encode_int(g, wnorm, uni, s, buf);
        }));
    }
    threads::pool().scope_run(tasks);
}

/// Parallel per-worker multi-scale encode at the shared coordinate scales.
pub fn encode_multiscale_into<T: LevelInt>(
    grads: &[&[f32]],
    wnorm: f32,
    table: &ScaleTable,
    shared_idx: &[u8],
    scratch: &mut Vec<Vec<T>>,
    uniform: &mut Vec<Vec<f32>>,
    rng: &Rng,
) {
    let m = grads.len();
    let n = grads[0].len();
    scratch.resize_with(m, Vec::new);
    uniform.resize_with(m, Vec::new);
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(m);
    for (w, ((buf, uni), g)) in scratch.iter_mut().zip(uniform.iter_mut()).zip(grads).enumerate() {
        let mut wrng = rng.derive(&[w as u64]);
        tasks.push(Box::new(move || {
            buf.resize(n, T::default());
            uni.resize(n, 0.0);
            wrng.fill_uniform_f32(uni);
            kernels::multiscale_encode_int(g, wnorm, uni, shared_idx, table, buf);
        }));
    }
    threads::pool().scope_run(tasks);
}

/// Parallel per-worker scale-index proposal (eq. 10) into reusable scratch.
pub fn scale_index_into(
    grads: &[&[f32]],
    wnorm: f32,
    table: &ScaleTable,
    idx_scratch: &mut Vec<Vec<u8>>,
) {
    let m = grads.len();
    let n = grads[0].len();
    idx_scratch.resize_with(m, Vec::new);
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(m);
    for (idx, g) in idx_scratch.iter_mut().zip(grads) {
        tasks.push(Box::new(move || {
            idx.resize(n, 0);
            kernels::multiscale_scale_index_t(g, wnorm, table, idx);
        }));
    }
    threads::pool().scope_run(tasks);
}

/// One full integer-domain QSGD step at a chosen accumulator width:
/// pool-parallel encode into `scratch`, in-place integer all-reduce
/// (charging `wire_bits`/coord), decode of the exact sum into `out`.
/// The single body behind both arms of every aggregator's i16/i32 dispatch.
#[allow(clippy::too_many_arguments)]
pub fn qsgd_step_int<T: LevelInt>(
    grads: &[&[f32]],
    wnorm: f32,
    s: usize,
    wire_bits: f64,
    scratch: &mut Vec<Vec<T>>,
    uniform: &mut Vec<Vec<f32>>,
    ctx: &mut StepCtx,
    rng: &Rng,
    out: &mut [f32],
) {
    let m = grads.len();
    // explicit reborrows: the closures must capture borrows of the &mut
    // params, not move them, so the later stages can reuse the buffers
    ctx.time_encode(|| encode_qsgd_into(grads, wnorm, s, &mut *scratch, &mut *uniform, rng));
    ctx.allreduce_sum_in_place_int(&mut *scratch, wire_bits);
    ctx.time_decode(|| kernels::qsgd_decode_sum_int(&scratch[0], wnorm, s, m, &mut *out));
}

/// Multi-scale analogue of [`qsgd_step_int`]: encode at the shared
/// per-coordinate scales, integer all-reduce, decode via the scale table.
#[allow(clippy::too_many_arguments)]
pub fn multiscale_step_int<T: LevelInt>(
    grads: &[&[f32]],
    wnorm: f32,
    table: &ScaleTable,
    shared_idx: &[u8],
    payload_bits: f64,
    scratch: &mut Vec<Vec<T>>,
    uniform: &mut Vec<Vec<f32>>,
    ctx: &mut StepCtx,
    rng: &Rng,
    out: &mut [f32],
) {
    let m = grads.len();
    ctx.time_encode(|| {
        encode_multiscale_into(grads, wnorm, table, shared_idx, &mut *scratch, &mut *uniform, rng)
    });
    ctx.allreduce_sum_in_place_int(&mut *scratch, payload_bits);
    ctx.time_decode(|| {
        kernels::multiscale_decode_sum_int(&scratch[0], wnorm, shared_idx, table, m, &mut *out)
    });
}

// ---------------------------------------------------------------------------
// Packed-resident chunk-pipelined hot path
// ---------------------------------------------------------------------------

/// Cross-step scratch of the packed-resident pipelined path: per-worker
/// resident packed word buffers plus per-chunk integer encode temporaries.
/// Zero steady-state allocation once warm, like the int-path scratch.
#[derive(Default)]
pub struct PackedScratch {
    words: Vec<Vec<u64>>,
    chunk_tmp: Vec<Vec<i32>>,
}

impl PackedScratch {
    pub fn new() -> PackedScratch {
        PackedScratch::default()
    }
}

/// Parallel per-worker uniform fill (`rng.derive([w])`, full length) as a
/// pre-pass: the uniform stream per worker is one sequential draw exactly
/// like the int path's, which is what makes the pipelined output invariant
/// to the chunk plan (xoshiro has no cheap arbitrary jump-ahead).
pub fn fill_uniforms_into(m: usize, n: usize, uniform: &mut Vec<Vec<f32>>, rng: &Rng) {
    uniform.resize_with(m, Vec::new);
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(m);
    for (w, uni) in uniform.iter_mut().enumerate() {
        let mut wrng = rng.derive(&[w as u64]);
        tasks.push(Box::new(move || {
            uni.resize(n, 0.0);
            wrng.fill_uniform_f32(uni);
        }));
    }
    threads::pool().scope_run(tasks);
}

/// [`fill_uniforms_into`] for a partial cohort: slot `i` draws the stream of
/// ORIGINAL worker id `ids[i]` (`rng.derive([ids[i]])`), not of its position
/// in the surviving slice. This is what keeps an elastic run replayable — a
/// worker that drops and later rejoins resumes its own per-step stream, so a
/// drop-then-rejoin schedule matches an independently constructed run over
/// the same cohort (pinned in `tests/int_domain_equivalence.rs`). With
/// `ids == [0, 1, .., m-1]` this IS `fill_uniforms_into(m, ..)` exactly.
pub fn fill_uniforms_masked_into(ids: &[usize], n: usize, uniform: &mut Vec<Vec<f32>>, rng: &Rng) {
    uniform.resize_with(ids.len(), Vec::new);
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(ids.len());
    for (&w, uni) in ids.iter().zip(uniform.iter_mut()) {
        let mut wrng = rng.derive(&[w as u64]);
        tasks.push(Box::new(move || {
            uni.resize(n, 0.0);
            wrng.fill_uniform_f32(uni);
        }));
    }
    threads::pool().scope_run(tasks);
}

/// Chunk boundaries for the encode/reduce pipeline: roughly even, but every
/// interior boundary is snapped down to a multiple of the word-alignment
/// period so no two chunks share a `u64` word of the resident buffers —
/// the disjointness that lets producer tasks pack concurrently.
fn chunk_plan(n: usize, resident_bits: u32, chunks: Option<usize>) -> Vec<usize> {
    if n == 0 {
        return vec![0];
    }
    let period = bitpack::codes_per_word_period(resident_bits);
    let want = chunks
        .unwrap_or_else(|| 2 * (threads::pool().threads() + 1))
        .max(1);
    let mut bounds = Vec::with_capacity(want + 1);
    bounds.push(0usize);
    for c in 1..want {
        let aligned = (c * n / want) / period * period;
        if aligned > *bounds.last().unwrap() && aligned < n {
            bounds.push(aligned);
        }
    }
    bounds.push(n);
    bounds
}

/// The engine behind both packed step functions: chunk-pipelined
/// encode→pack→packed-reduce→decode over the persistent pool, generic over
/// the reduction schedule ([`collectives::PackedReduce`]).
///
/// For each chunk (word-aligned code range of the per-worker resident
/// buffers), a producer task encodes every worker's slice into an integer
/// temp and packs it as biased codes at the resident width; **as soon as a
/// chunk is packed it enters the reduce** on the consuming (calling) thread
/// while later chunks are still encoding — chunks are independent
/// sub-all-reduces, and integer sums are exact, so completion order cannot
/// change the result. The consumer reduces the chunk through the schedule
/// (fixed/growing ring, tree, or naive — all packed-resident) and
/// immediately decodes it into `out`.
///
/// Timing attribution (see DESIGN.md §Performance): decode work is measured
/// into `decode_s`; the rest of the overlapped produce/reduce wall time
/// lands in `encode_s`; the simulated wire cost is charged separately and
/// hop-accurately by the caller via `StepCtx::charge_packed`.
#[allow(clippy::too_many_arguments)]
fn packed_pipeline(
    m: usize,
    n: usize,
    resident_bits: u32,
    chunks: Option<usize>,
    sched: &dyn collectives::PackedReduce,
    scratch: &mut PackedScratch,
    ctx: &mut StepCtx,
    encode_chunk: impl Fn(usize, usize, usize, &mut Vec<i32>, &mut [u64]) + Send + Sync,
    mut decode_chunk: impl FnMut(usize, usize, &[u64]),
) -> collectives::PlaneTraffic {
    let words_len = bitpack::words_for(n, resident_bits);
    scratch.words.resize_with(m, Vec::new);
    for wbuf in scratch.words.iter_mut() {
        // no zero-fill pass: producers fully overwrite every covered word
        // (interior chunk boundaries are word-aligned, and the tail word's
        // slack bits above n*resident_bits are never read by unpack/add/
        // copy) — only fresh capacity needs defined contents
        wbuf.resize(words_len, 0);
    }
    let bounds = chunk_plan(n, resident_bits, chunks);
    let nchunks = bounds.len().saturating_sub(1);
    scratch.chunk_tmp.resize_with(nchunks, Vec::new);

    let word_ptrs: Vec<threads::SendPtr<u64>> = scratch
        .words
        .iter_mut()
        .map(|w| threads::SendPtr(w.as_mut_ptr()))
        .collect();
    let tmp_ptr = threads::SendPtr(scratch.chunk_tmp.as_mut_ptr());
    let rb = resident_bits as usize;

    let mut traffic = collectives::PlaneTraffic::default();
    let mut decode_s = 0.0f64;
    let t0 = std::time::Instant::now();
    {
        let bounds = &bounds;
        let word_ptrs = &word_ptrs;
        let encode_chunk = &encode_chunk;
        let traffic = &mut traffic;
        let decode_s = &mut decode_s;
        threads::pool().pipeline_chunks(
            nchunks,
            move |c| {
                let (lo, hi) = (bounds[c], bounds[c + 1]);
                // chunk c covers words [lo*rb/64, ceil(hi*rb/64)); the start
                // is word-exact because the plan aligns interior boundaries
                let (w_lo, w_hi) = (lo * rb / 64, (hi * rb).div_ceil(64));
                // SAFETY: chunk word ranges and chunk_tmp slots are disjoint
                // across chunks (aligned boundaries), each touched by exactly
                // one producer; the consumer touches a chunk only after its
                // producer settled (happens-before via the ready queue).
                let tmp = unsafe { &mut *tmp_ptr.0.add(c) };
                for wk in 0..m {
                    let wslice = unsafe {
                        std::slice::from_raw_parts_mut(word_ptrs[wk].0.add(w_lo), w_hi - w_lo)
                    };
                    encode_chunk(wk, lo, hi, tmp, wslice);
                }
            },
            |c| {
                let (lo, hi) = (bounds[c], bounds[c + 1]);
                let (w_lo, w_hi) = (lo * rb / 64, (hi * rb).div_ceil(64));
                // SAFETY: as above — the producer for chunk c has settled.
                let mut views: Vec<&mut [u64]> = word_ptrs
                    .iter()
                    .map(|p| unsafe {
                        std::slice::from_raw_parts_mut(p.0.add(w_lo), w_hi - w_lo)
                    })
                    .collect();
                sched.reduce(&mut views, resident_bits, hi - lo, traffic);
                let td = std::time::Instant::now();
                decode_chunk(lo, hi, &*views[0]);
                *decode_s += td.elapsed().as_secs_f64();
            },
        );
    }
    let wall = t0.elapsed().as_secs_f64();
    let e0 = ctx.clock.encode_s;
    ctx.clock.encode_s += (wall - decode_s).max(0.0);
    let d0 = ctx.clock.decode_s;
    ctx.clock.decode_s += decode_s;
    if let Some(t) = ctx.tracer.as_deref_mut() {
        let bucket = t.bucket();
        t.push(crate::trace::Span::new(
            crate::trace::Cat::Encode,
            crate::trace::SpanKind::Encode { bucket },
            e0,
            ctx.clock.encode_s,
            0.0,
        ));
        t.push(crate::trace::Span::new(
            crate::trace::Cat::Decode,
            crate::trace::SpanKind::Decode { bucket },
            d0,
            ctx.clock.decode_s,
            0.0,
        ));
    }
    traffic
}

/// One full packed-resident pipelined QSGD step: per-chunk pool-parallel
/// encode into biased packed codes, chunk-pipelined packed reduce through
/// the schedule resolved from the step context (fixed/growing ring, tree,
/// naive — the resident reduce operand is `Packed` words for all of them),
/// per-chunk decode of the exact integer sum, hop-accurate wire charging.
/// Bit-identical to [`qsgd_step_int`] (and hence to the legacy f32 path)
/// for any schedule and chunk plan. `chunks` forces the chunk count
/// (tests); `None` auto-sizes to the pool.
#[allow(clippy::too_many_arguments)]
pub fn qsgd_step_packed(
    grads: &[&[f32]],
    wnorm: f32,
    s: usize,
    wire_bits: f64,
    scratch: &mut PackedScratch,
    uniform: &mut Vec<Vec<f32>>,
    ctx: &mut StepCtx,
    rng: &Rng,
    chunks: Option<usize>,
    out: &mut [f32],
) -> collectives::PlaneTraffic {
    let m = grads.len();
    let n = grads[0].len();
    ctx.time_encode(|| fill_uniforms_into(m, n, uniform, rng));
    let uni: Vec<&[f32]> = uniform.iter().map(|u| u.as_slice()).collect();
    qsgd_step_packed_with_uniforms(grads, &uni, wnorm, s, wire_bits, scratch, ctx, chunks, out)
}

/// [`qsgd_step_packed`] with caller-provided per-worker uniform slices.
///
/// This is the seam the bucketed control plane ([`crate::control`]) drives:
/// it draws ONE full-length uniform stream per worker (exactly the
/// monolithic step's `rng.derive([w])` draw) and hands each bucket its
/// slice, so — when every bucket also shares the monolithic global norm
/// (the control plane's non-overlapped mode) — the bucketed output is
/// bit-identical to the monolithic packed step for any bucket plan. The
/// wire is charged per call — per bucket — at byte-exact
/// `ceil(len * wire_bits / 8)` through [`StepCtx::charge_packed`].
#[allow(clippy::too_many_arguments)]
pub fn qsgd_step_packed_with_uniforms(
    grads: &[&[f32]],
    uni: &[&[f32]],
    wnorm: f32,
    s: usize,
    wire_bits: f64,
    scratch: &mut PackedScratch,
    ctx: &mut StepCtx,
    chunks: Option<usize>,
    out: &mut [f32],
) -> collectives::PlaneTraffic {
    let m = grads.len();
    let n = grads[0].len();
    assert!(
        sum_fits::<i32>(s, m),
        "widening rule: {m} workers x s={s} overflows i32"
    );
    // release-mode backstop behind the pre-encode GradGuard scan: a
    // non-finite shared norm poisons every level drawn from it, so fail
    // loudly here rather than ship garbage codes
    assert!(wnorm.is_finite(), "non-finite gradient norm reached the encoder: {wnorm}");
    debug_assert!(uni.len() == m && uni.iter().all(|u| u.len() >= n));
    let rbits = bitpack::packed_sum_bits(s.max(1), m);
    let sched = ctx.packed_schedule(s.max(1), m, n);
    let bias = s as i64;
    let bias_total = (m as i64) * bias;
    // same float expression as `kernels::qsgd_decode_sum_int`
    let k = wnorm / (s as f32 * m as f32);
    let traffic = packed_pipeline(
        m,
        n,
        rbits,
        chunks,
        sched.as_dyn(),
        scratch,
        ctx,
        |wk, lo, hi, tmp, wslice| {
            tmp.resize(hi - lo, 0);
            kernels::qsgd_encode_int(&grads[wk][lo..hi], wnorm, &uni[wk][lo..hi], s, &mut tmp[..]);
            // i32-specialized biased pack: SIMD code materialization with a
            // loud lane-wise range check (bit-identical to the generic path)
            bitpack::pack_biased_i32_at(&tmp[..], bias, rbits, wslice, 0);
        },
        |lo, hi, sum_words| {
            let dst = &mut out[lo..hi];
            bitpack::unpack_codes_at_with(sum_words, rbits, 0, hi - lo, |i, code| {
                // mirror of qsgd_decode_sum_int: exact integer sum -> f32 * k
                let z = code as i64 - bias_total;
                dst[i] = (z as f32) * k;
            });
        },
    );
    ctx.charge_packed(sched.as_dyn(), n, rbits, wire_bits);
    traffic
}

/// Multi-scale analogue of [`qsgd_step_packed`]: encode at the shared
/// per-coordinate scales (levels bounded by `s_min + 1`, eq. 10), packed
/// reduce through the resolved schedule, per-chunk decode via the scale
/// table. Bit-identical to [`multiscale_step_int`] for any schedule and
/// chunk plan.
#[allow(clippy::too_many_arguments)]
pub fn multiscale_step_packed(
    grads: &[&[f32]],
    wnorm: f32,
    table: &ScaleTable,
    shared_idx: &[u8],
    payload_bits: f64,
    scratch: &mut PackedScratch,
    uniform: &mut Vec<Vec<f32>>,
    ctx: &mut StepCtx,
    rng: &Rng,
    chunks: Option<usize>,
    out: &mut [f32],
) -> collectives::PlaneTraffic {
    let m = grads.len();
    let n = grads[0].len();
    ctx.time_encode(|| fill_uniforms_into(m, n, uniform, rng));
    let uni: Vec<&[f32]> = uniform.iter().map(|u| u.as_slice()).collect();
    multiscale_step_packed_with_uniforms(
        grads, &uni, wnorm, table, shared_idx, payload_bits, scratch, ctx, chunks, out,
    )
}

/// [`multiscale_step_packed`] with caller-provided per-worker uniform
/// slices AND the caller's per-coordinate scale share.
///
/// This is the multi-scale arm of the bucketed control plane's seam
/// ([`crate::control`]), mirroring [`qsgd_step_packed_with_uniforms`]: the
/// plane draws ONE full-length uniform stream per worker (the monolithic
/// `rng.derive([w])` draw) and hands each bucket its slice of the stream
/// and its slice of the scale share. Because the scale share is an
/// *elementwise* min all-reduce, a per-bucket share derived from the
/// bucket's own proposals equals the slice of the global share whenever
/// the proposals were made against the same norm — so a bucketed FixedBits
/// multi-scale step with a global norm is bit-identical to the monolithic
/// packed step for any bucket plan and schedule. The wire is charged per
/// call — per bucket — byte-exactly through [`StepCtx::charge_packed`].
#[allow(clippy::too_many_arguments)]
pub fn multiscale_step_packed_with_uniforms(
    grads: &[&[f32]],
    uni: &[&[f32]],
    wnorm: f32,
    table: &ScaleTable,
    shared_idx: &[u8],
    payload_bits: f64,
    scratch: &mut PackedScratch,
    ctx: &mut StepCtx,
    chunks: Option<usize>,
    out: &mut [f32],
) -> collectives::PlaneTraffic {
    let m = grads.len();
    let n = grads[0].len();
    let lmax = table.smin as usize + 1; // eq. (10): levels <= s_min + 1
    assert!(
        sum_fits::<i32>(lmax, m),
        "widening rule: {m} workers x lmax={lmax} overflows i32"
    );
    // release-mode backstop behind the pre-encode GradGuard scan (see
    // qsgd_step_packed_with_uniforms)
    assert!(wnorm.is_finite(), "non-finite gradient norm reached the encoder: {wnorm}");
    debug_assert!(uni.len() == m && uni.iter().all(|u| u.len() >= n));
    debug_assert!(shared_idx.len() >= n);
    let rbits = bitpack::packed_sum_bits(lmax, m);
    let sched = ctx.packed_schedule(lmax, m, n);
    let bias = lmax as i64;
    let bias_total = (m as i64) * bias;
    let mf = m as f32;
    let traffic = packed_pipeline(
        m,
        n,
        rbits,
        chunks,
        sched.as_dyn(),
        scratch,
        ctx,
        |wk, lo, hi, tmp, wslice| {
            tmp.resize(hi - lo, 0);
            kernels::multiscale_encode_int(
                &grads[wk][lo..hi],
                wnorm,
                &uni[wk][lo..hi],
                &shared_idx[lo..hi],
                table,
                &mut tmp[..],
            );
            // i32-specialized biased pack (see qsgd_step path)
            bitpack::pack_biased_i32_at(&tmp[..], bias, rbits, wslice, 0);
        },
        |lo, hi, sum_words| {
            let dst = &mut out[lo..hi];
            let idx = &shared_idx[lo..hi];
            bitpack::unpack_codes_at_with(sum_words, rbits, 0, hi - lo, |i, code| {
                // mirror of multiscale_decode_sum_int's float op order.
                // decode boundary: the share indices crossed the wire, so a
                // poisoned index must panic here, not divide by the 0.0
                // padding lane into silent ±inf gradients (satellite 2).
                let z = (code as i64 - bias_total) as f32;
                let s_sel = table.select_checked(idx[i] as u32);
                dst[i] = z * wnorm / (s_sel * mf);
            });
        },
    );
    ctx.charge_packed(sched.as_dyn(), n, rbits, payload_bits);
    traffic
}

/// The legacy f32-level QSGD-MN aggregation (encode f32 → f32 ring
/// all-reduce → in-place decode), preserved verbatim as the baseline the
/// integer-domain path is property-tested bit-identical to and benchmarked
/// against. Not used by the production aggregators.
pub fn reference_qsgd_aggregate(grads: &[&[f32]], wnorm: f32, s: usize, rng: &Rng) -> Vec<f32> {
    let m = grads.len();
    let n = grads[0].len();
    let mut bufs: Vec<Vec<f32>> = Vec::with_capacity(m);
    for (w, g) in grads.iter().enumerate() {
        let mut wrng = rng.derive(&[w as u64]);
        let mut uni = vec![0.0f32; n];
        wrng.fill_uniform_f32(&mut uni);
        let mut buf = vec![0.0f32; n];
        kernels::qsgd_encode(g, wnorm, &uni, s, &mut buf);
        bufs.push(buf);
    }
    collectives::ring_allreduce_sum(&mut bufs);
    let mut sum = bufs.swap_remove(0);
    kernels::qsgd_decode_sum(&mut sum, wnorm, s, m);
    sum
}

/// Fused integer pipeline WITH the packed wire hop:
/// encode → pack(b bits) → unpack → integer ring all-reduce → decode.
/// Returns the averaged gradient and the packed wire bytes per worker.
/// The pack/unpack round-trip is the wire format the simulator charges
/// for; running it in the data plane proves it lossless end-to-end.
pub fn wire_roundtrip_qsgd<T: LevelInt>(
    grads: &[&[f32]],
    wnorm: f32,
    bits: usize,
    rng: &Rng,
) -> (Vec<f32>, usize) {
    let m = grads.len();
    let n = grads[0].len();
    let s = kernels::s_for_bits(bits);
    assert!(
        sum_fits::<T>(s, m),
        "widening rule: {m} workers x s={s} overflows {}",
        T::TAG
    );
    let mut scratch: Vec<Vec<T>> = Vec::new();
    let mut uniform: Vec<Vec<f32>> = Vec::new();
    encode_qsgd_into(grads, wnorm, s, &mut scratch, &mut uniform, rng);

    let mut wire_bytes = 0;
    for buf in scratch.iter_mut() {
        let packed = bitpack::pack_int(buf, bits as u32);
        wire_bytes = packed.wire_bytes();
        buf.fill(T::default()); // prove decode uses only wire data
        bitpack::unpack_int_into(&packed, buf);
    }

    collectives::ring_allreduce_sum_t(&mut scratch);
    let mut out = vec![0.0f32; n];
    kernels::qsgd_decode_sum_int(&scratch[0], wnorm, s, m, &mut out);
    (out, wire_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::kernels::l2_norm;
    use crate::util::quickcheck::{check, ensure};

    #[test]
    fn masked_uniform_fill_keys_streams_by_original_worker_id() {
        let rng = Rng::new(0xE1A5);
        let (m, n) = (5usize, 97usize);
        let mut full = Vec::new();
        fill_uniforms_into(m, n, &mut full, &rng);

        // identity mask IS the plain fill
        let ids: Vec<usize> = (0..m).collect();
        let mut masked = Vec::new();
        fill_uniforms_masked_into(&ids, n, &mut masked, &rng);
        assert_eq!(masked, full);

        // a partial cohort draws each survivor's ORIGINAL stream: slot i of
        // the masked fill equals slot ids[i] of the full fill, bit for bit
        let cohort = [0usize, 1, 3];
        fill_uniforms_masked_into(&cohort, n, &mut masked, &rng);
        assert_eq!(masked.len(), cohort.len());
        for (i, &w) in cohort.iter().enumerate() {
            assert_eq!(masked[i], full[w], "slot {i} must replay worker {w}'s stream");
        }
    }

    #[test]
    fn prop_wire_roundtrip_matches_reference_bit_exact() {
        // the tentpole invariant: integer-domain encode→pack→allreduce→
        // unpack→decode == legacy f32-level path, bit for bit.
        check("fused wire path == f32 reference", 60, |g| {
            let m = g.usize_in(1, 8);
            let bits = *g.pick(&[2usize, 4, 6, 8, 12]);
            let n = g.size_scaled(1, 2000);
            let grads: Vec<Vec<f32>> = (0..m).map(|_| g.vec_normal(n, 1.0)).collect();
            let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
            let wnorm = refs.iter().map(|v| l2_norm(v)).fold(0.0f32, f32::max);
            let rng = Rng::new(g.rng().next_u64());

            let want = reference_qsgd_aggregate(&refs, wnorm, kernels::s_for_bits(bits), &rng);
            let s = kernels::s_for_bits(bits);
            let (got, wire) = if narrow_fits(s, m) {
                wire_roundtrip_qsgd::<i16>(&refs, wnorm, bits, &rng)
            } else {
                wire_roundtrip_qsgd::<i32>(&refs, wnorm, bits, &rng)
            };
            if got != want {
                let bad = got.iter().zip(&want).position(|(a, b)| a != b).unwrap();
                return Err(format!(
                    "bits={bits} m={m} n={n}: first diff at {bad}: {} vs {}",
                    got[bad], want[bad]
                ));
            }
            ensure(wire == (n * bits).div_ceil(8), "wire bytes must be byte-exact")
        });
    }

    #[test]
    fn prop_packed_pipelined_step_bit_identical_for_any_chunk_plan() {
        // the tentpole invariant at the step level: the chunk-pipelined
        // packed-resident path == the int path == the legacy f32 reference,
        // for chunk counts including 1 and far beyond the pool width.
        use crate::netsim::{NetConfig, SimClock};
        check("packed pipelined == int == f32 reference", 40, |g| {
            let m = g.usize_in(1, 8);
            let bits = *g.pick(&[2usize, 4, 6, 8, 12]);
            let n = g.size_scaled(1, 3000);
            let s = kernels::s_for_bits(bits);
            let grads: Vec<Vec<f32>> = (0..m).map(|_| g.vec_normal(n, 1.0)).collect();
            let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
            let wnorm = refs.iter().map(|v| l2_norm(v)).fold(0.0f32, f32::max);
            let seed = g.rng().next_u64();
            let want = reference_qsgd_aggregate(&refs, wnorm, s, &Rng::new(seed));

            let nchunks = *g.pick(&[1usize, 2, 3, 7, 64]);
            let net = NetConfig::flat(m, 10.0);
            let mut clock = SimClock::default();
            let mut ctx = StepCtx::new(&net, &mut clock);
            let mut scratch = PackedScratch::new();
            let mut uniform = Vec::new();
            let mut got = vec![0.0f32; n];
            qsgd_step_packed(
                &refs,
                wnorm,
                s,
                bits as f64,
                &mut scratch,
                &mut uniform,
                &mut ctx,
                &Rng::new(seed),
                Some(nchunks),
                &mut got,
            );
            if got != want {
                let bad = got.iter().zip(&want).position(|(a, b)| a != b).unwrap();
                return Err(format!(
                    "bits={bits} m={m} n={n} chunks={nchunks}: diff at {bad}: {} vs {}",
                    got[bad], want[bad]
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn packed_step_ledger_matches_int_step_ledger() {
        // the paper's nominal bits ledger must be identical across data
        // planes; only the hop-accurate books may differ.
        use crate::netsim::{NetConfig, SimClock};
        let m = 4;
        let n = 997; // odd on purpose: byte-exact rounding must agree
        let bits = 4usize;
        let s = kernels::s_for_bits(bits);
        let grads: Vec<Vec<f32>> = (0..m).map(|w| vec![0.1 * (w as f32 + 1.0); n]).collect();
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let wnorm = refs.iter().map(|v| l2_norm(v)).fold(0.0f32, f32::max);
        let net = NetConfig::flat(m, 10.0);

        let mut clock_int = SimClock::default();
        {
            let mut ctx = StepCtx::new(&net, &mut clock_int);
            let mut scratch: Vec<Vec<i16>> = Vec::new();
            let mut uniform = Vec::new();
            let mut out = vec![0.0f32; n];
            qsgd_step_int(
                &refs, wnorm, s, bits as f64, &mut scratch, &mut uniform, &mut ctx,
                &Rng::new(9), &mut out,
            );
        }
        let mut clock_packed = SimClock::default();
        {
            let mut ctx = StepCtx::new(&net, &mut clock_packed);
            let mut scratch = PackedScratch::new();
            let mut uniform = Vec::new();
            let mut out = vec![0.0f32; n];
            qsgd_step_packed(
                &refs, wnorm, s, bits as f64, &mut scratch, &mut uniform, &mut ctx,
                &Rng::new(9), None, &mut out,
            );
        }
        assert_eq!(clock_int.bits_per_worker, clock_packed.bits_per_worker);
        assert_eq!(clock_int.hop_bits_per_worker, 0.0);
        assert!(clock_packed.hop_bits_per_worker > clock_packed.bits_per_worker);
    }

    #[test]
    fn hier_schedule_step_bit_identical_with_per_level_ledger() {
        // PR 8 through the fused seam: with ctx.hier on a multi-island net,
        // the step resolves the two-level schedule, the payload stays bit-
        // identical to the flat reference, and the hop-bits book splits per
        // link level (closed forms in the collectives tests; here we pin
        // the seam: both levels charged, sum preserved, comm_s cheaper).
        use crate::netsim::{NetConfig, SimClock};
        let m = 8usize;
        let bits = 4usize;
        let n = 1003;
        let s = kernels::s_for_bits(bits);
        let grads: Vec<Vec<f32>> = (0..m).map(|w| vec![0.07 * (w as f32 - 3.0); n]).collect();
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let wnorm = refs.iter().map(|v| l2_norm(v)).fold(0.0f32, f32::max);
        let want = reference_qsgd_aggregate(&refs, wnorm, s, &Rng::new(11));

        let mut net = NetConfig::flat(m, 10.0);
        net.gpus_per_node = 4; // 2 islands x 4 GPUs
        let run = |hier: bool| {
            let mut clock = SimClock::default();
            let mut ctx = StepCtx::new(&net, &mut clock);
            ctx.hier = hier;
            let mut scratch = PackedScratch::new();
            let mut uniform = Vec::new();
            let mut out = vec![0.0f32; n];
            qsgd_step_packed(
                &refs, wnorm, s, bits as f64, &mut scratch, &mut uniform, &mut ctx,
                &Rng::new(11), Some(3), &mut out,
            );
            (out, clock)
        };
        let (flat_out, flat_clock) = run(false);
        let (hier_out, hier_clock) = run(true);
        assert_eq!(flat_out, want, "flat payload vs f32 reference");
        assert_eq!(hier_out, want, "hier payload must be bit-identical");
        // nominal ledger identical across schedules; per-level split only
        // on the hierarchical run (the flat net books everything Inter)
        assert_eq!(flat_clock.bits_per_worker, hier_clock.bits_per_worker);
        assert_eq!(flat_clock.hop_bits_intra, 0.0);
        assert!(hier_clock.hop_bits_intra > 0.0);
        assert!(hier_clock.hop_bits_inter > 0.0);
        assert_eq!(
            hier_clock.hop_bits_intra + hier_clock.hop_bits_inter,
            hier_clock.hop_bits_per_worker
        );
        // islands of 4 keep 3/4 of the flat ring's traffic off Ethernet
        assert!(hier_clock.comm_s < flat_clock.comm_s);
    }

    #[test]
    fn widening_rule_bounds() {
        assert!(narrow_fits(7, 4096)); // 4-bit, max workers: 28672 < 32767
        assert!(!narrow_fits(2047, 17)); // 12-bit: 17 * 2047 > i16::MAX
        assert!(assert_widening_rule(32767).is_ok()); // 16-bit at MAX_WORKERS
    }
}
