//! Fused integer-domain hot path shared by the all-reduce-compatible
//! aggregators (QSGD-MN, QSGD-MN-TS, GRandK variants).
//!
//! The pre-integer pipeline carried quantizer levels as `f32`: 32 bits per
//! coordinate through encode, the ring all-reduce, and decode — for a
//! nominally 2–16-bit wire format. Exactly the gap ScaleCom (Chen et al.,
//! 2020) identifies between paper speedups and deployed speedups. Here the
//! levels are written straight into widened integer buffers
//! ([`LevelInt`]: `i16` when `workers * s` fits, `i32` otherwise — the
//! overflow-safe widening rule), reduced in the integer domain, and decoded
//! once from the exact integer sum. Encode fan-out runs on the persistent
//! [`threads::pool`] instead of spawning OS threads per step, and every
//! buffer lives in the aggregator across steps.
//!
//! [`wire_roundtrip_qsgd`] additionally pushes each worker's levels through
//! the packed wire format (`bitpack`) before reducing — the property tests
//! use it to pin the full encode→pack→allreduce→unpack→decode chain
//! bit-identical to the legacy f32 path ([`reference_qsgd_aggregate`]).

use crate::collectives::{self, StepCtx};
use crate::tensor::{sum_fits, LevelInt};
use crate::util::rng::Rng;
use crate::util::threads;

use super::bitpack;
use super::kernels::{self, ScaleTable};

/// Hard cap on simulated workers for the integer-domain aggregators. The
/// constructors assert `MAX_WORKERS * s <= i32::MAX`, making overflow
/// impossible by construction anywhere below this bound (for b <= 16,
/// `s <= 32767`, so `4096 * s <= 1.35e8` — two orders under `i32::MAX`).
pub const MAX_WORKERS: usize = 4096;

/// Construction-time overflow proof for a quantizer with `s` levels.
pub fn assert_widening_rule(s: usize) -> anyhow::Result<()> {
    anyhow::ensure!(
        sum_fits::<i32>(s, MAX_WORKERS),
        "widening rule violated: {MAX_WORKERS} workers x s={s} overflows i32"
    );
    Ok(())
}

/// Does the narrow (i16) accumulator suffice for this step?
pub fn narrow_fits(s: usize, workers: usize) -> bool {
    sum_fits::<i16>(s, workers)
}

/// Parallel per-worker QSGD encode into reusable integer scratch. Worker
/// streams derive from `rng` exactly like the legacy path (`derive([w])`),
/// so outputs are bit-identical given the same step rng.
pub fn encode_qsgd_into<T: LevelInt>(
    grads: &[&[f32]],
    wnorm: f32,
    s: usize,
    scratch: &mut Vec<Vec<T>>,
    uniform: &mut Vec<Vec<f32>>,
    rng: &Rng,
) {
    let m = grads.len();
    let n = grads[0].len();
    scratch.resize_with(m, Vec::new);
    uniform.resize_with(m, Vec::new);
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(m);
    for (w, ((buf, uni), g)) in scratch.iter_mut().zip(uniform.iter_mut()).zip(grads).enumerate() {
        let mut wrng = rng.derive(&[w as u64]);
        tasks.push(Box::new(move || {
            buf.resize(n, T::default());
            uni.resize(n, 0.0);
            wrng.fill_uniform_f32(uni);
            kernels::qsgd_encode_int(g, wnorm, uni, s, buf);
        }));
    }
    threads::pool().scope_run(tasks);
}

/// Parallel per-worker multi-scale encode at the shared coordinate scales.
pub fn encode_multiscale_into<T: LevelInt>(
    grads: &[&[f32]],
    wnorm: f32,
    table: &ScaleTable,
    shared_idx: &[u8],
    scratch: &mut Vec<Vec<T>>,
    uniform: &mut Vec<Vec<f32>>,
    rng: &Rng,
) {
    let m = grads.len();
    let n = grads[0].len();
    scratch.resize_with(m, Vec::new);
    uniform.resize_with(m, Vec::new);
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(m);
    for (w, ((buf, uni), g)) in scratch.iter_mut().zip(uniform.iter_mut()).zip(grads).enumerate() {
        let mut wrng = rng.derive(&[w as u64]);
        tasks.push(Box::new(move || {
            buf.resize(n, T::default());
            uni.resize(n, 0.0);
            wrng.fill_uniform_f32(uni);
            kernels::multiscale_encode_int(g, wnorm, uni, shared_idx, table, buf);
        }));
    }
    threads::pool().scope_run(tasks);
}

/// Parallel per-worker scale-index proposal (eq. 10) into reusable scratch.
pub fn scale_index_into(
    grads: &[&[f32]],
    wnorm: f32,
    table: &ScaleTable,
    idx_scratch: &mut Vec<Vec<u8>>,
) {
    let m = grads.len();
    let n = grads[0].len();
    idx_scratch.resize_with(m, Vec::new);
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(m);
    for (idx, g) in idx_scratch.iter_mut().zip(grads) {
        tasks.push(Box::new(move || {
            idx.resize(n, 0);
            kernels::multiscale_scale_index_t(g, wnorm, table, idx);
        }));
    }
    threads::pool().scope_run(tasks);
}

/// One full integer-domain QSGD step at a chosen accumulator width:
/// pool-parallel encode into `scratch`, in-place integer all-reduce
/// (charging `wire_bits`/coord), decode of the exact sum into `out`.
/// The single body behind both arms of every aggregator's i16/i32 dispatch.
#[allow(clippy::too_many_arguments)]
pub fn qsgd_step_int<T: LevelInt>(
    grads: &[&[f32]],
    wnorm: f32,
    s: usize,
    wire_bits: f64,
    scratch: &mut Vec<Vec<T>>,
    uniform: &mut Vec<Vec<f32>>,
    ctx: &mut StepCtx,
    rng: &Rng,
    out: &mut [f32],
) {
    let m = grads.len();
    // explicit reborrows: the closures must capture borrows of the &mut
    // params, not move them, so the later stages can reuse the buffers
    ctx.time_encode(|| encode_qsgd_into(grads, wnorm, s, &mut *scratch, &mut *uniform, rng));
    ctx.allreduce_sum_in_place_int(&mut *scratch, wire_bits);
    ctx.time_decode(|| kernels::qsgd_decode_sum_int(&scratch[0], wnorm, s, m, &mut *out));
}

/// Multi-scale analogue of [`qsgd_step_int`]: encode at the shared
/// per-coordinate scales, integer all-reduce, decode via the scale table.
#[allow(clippy::too_many_arguments)]
pub fn multiscale_step_int<T: LevelInt>(
    grads: &[&[f32]],
    wnorm: f32,
    table: &ScaleTable,
    shared_idx: &[u8],
    payload_bits: f64,
    scratch: &mut Vec<Vec<T>>,
    uniform: &mut Vec<Vec<f32>>,
    ctx: &mut StepCtx,
    rng: &Rng,
    out: &mut [f32],
) {
    let m = grads.len();
    ctx.time_encode(|| {
        encode_multiscale_into(grads, wnorm, table, shared_idx, &mut *scratch, &mut *uniform, rng)
    });
    ctx.allreduce_sum_in_place_int(&mut *scratch, payload_bits);
    ctx.time_decode(|| {
        kernels::multiscale_decode_sum_int(&scratch[0], wnorm, shared_idx, table, m, &mut *out)
    });
}

/// The legacy f32-level QSGD-MN aggregation (encode f32 → f32 ring
/// all-reduce → in-place decode), preserved verbatim as the baseline the
/// integer-domain path is property-tested bit-identical to and benchmarked
/// against. Not used by the production aggregators.
pub fn reference_qsgd_aggregate(grads: &[&[f32]], wnorm: f32, s: usize, rng: &Rng) -> Vec<f32> {
    let m = grads.len();
    let n = grads[0].len();
    let mut bufs: Vec<Vec<f32>> = Vec::with_capacity(m);
    for (w, g) in grads.iter().enumerate() {
        let mut wrng = rng.derive(&[w as u64]);
        let mut uni = vec![0.0f32; n];
        wrng.fill_uniform_f32(&mut uni);
        let mut buf = vec![0.0f32; n];
        kernels::qsgd_encode(g, wnorm, &uni, s, &mut buf);
        bufs.push(buf);
    }
    collectives::ring_allreduce_sum(&mut bufs);
    let mut sum = bufs.swap_remove(0);
    kernels::qsgd_decode_sum(&mut sum, wnorm, s, m);
    sum
}

/// Fused integer pipeline WITH the packed wire hop:
/// encode → pack(b bits) → unpack → integer ring all-reduce → decode.
/// Returns the averaged gradient and the packed wire bytes per worker.
/// The pack/unpack round-trip is the wire format the simulator charges
/// for; running it in the data plane proves it lossless end-to-end.
pub fn wire_roundtrip_qsgd<T: LevelInt>(
    grads: &[&[f32]],
    wnorm: f32,
    bits: usize,
    rng: &Rng,
) -> (Vec<f32>, usize) {
    let m = grads.len();
    let n = grads[0].len();
    let s = kernels::s_for_bits(bits);
    assert!(
        sum_fits::<T>(s, m),
        "widening rule: {m} workers x s={s} overflows {}",
        T::TAG
    );
    let mut scratch: Vec<Vec<T>> = Vec::new();
    let mut uniform: Vec<Vec<f32>> = Vec::new();
    encode_qsgd_into(grads, wnorm, s, &mut scratch, &mut uniform, rng);

    let mut wire_bytes = 0;
    for buf in scratch.iter_mut() {
        let packed = bitpack::pack_int(buf, bits as u32);
        wire_bytes = packed.wire_bytes();
        buf.fill(T::default()); // prove decode uses only wire data
        bitpack::unpack_int_into(&packed, buf);
    }

    collectives::ring_allreduce_sum_t(&mut scratch);
    let mut out = vec![0.0f32; n];
    kernels::qsgd_decode_sum_int(&scratch[0], wnorm, s, m, &mut out);
    (out, wire_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::kernels::l2_norm;
    use crate::util::quickcheck::{check, ensure};

    #[test]
    fn prop_wire_roundtrip_matches_reference_bit_exact() {
        // the tentpole invariant: integer-domain encode→pack→allreduce→
        // unpack→decode == legacy f32-level path, bit for bit.
        check("fused wire path == f32 reference", 60, |g| {
            let m = g.usize_in(1, 8);
            let bits = *g.pick(&[2usize, 4, 6, 8, 12]);
            let n = g.size_scaled(1, 2000);
            let grads: Vec<Vec<f32>> = (0..m).map(|_| g.vec_normal(n, 1.0)).collect();
            let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
            let wnorm = refs.iter().map(|v| l2_norm(v)).fold(0.0f32, f32::max);
            let rng = Rng::new(g.rng().next_u64());

            let want = reference_qsgd_aggregate(&refs, wnorm, kernels::s_for_bits(bits), &rng);
            let s = kernels::s_for_bits(bits);
            let (got, wire) = if narrow_fits(s, m) {
                wire_roundtrip_qsgd::<i16>(&refs, wnorm, bits, &rng)
            } else {
                wire_roundtrip_qsgd::<i32>(&refs, wnorm, bits, &rng)
            };
            if got != want {
                let bad = got.iter().zip(&want).position(|(a, b)| a != b).unwrap();
                return Err(format!(
                    "bits={bits} m={m} n={n}: first diff at {bad}: {} vs {}",
                    got[bad], want[bad]
                ));
            }
            ensure(wire == (n * bits).div_ceil(8), "wire bytes must be byte-exact")
        });
    }

    #[test]
    fn widening_rule_bounds() {
        assert!(narrow_fits(7, 4096)); // 4-bit, max workers: 28672 < 32767
        assert!(!narrow_fits(2047, 17)); // 12-bit: 17 * 2047 > i16::MAX
        assert!(assert_widening_rule(32767).is_ok()); // 16-bit at MAX_WORKERS
    }
}
