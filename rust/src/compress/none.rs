//! AllReduce-SGD: the dense fp32 baseline (PyTorch's default aggregation).

use crate::collectives::StepCtx;
use crate::util::rng::Rng;

use super::Aggregator;

pub struct DenseAllReduce;

impl DenseAllReduce {
    pub fn new() -> DenseAllReduce {
        DenseAllReduce
    }
}

impl Default for DenseAllReduce {
    fn default() -> Self {
        Self::new()
    }
}

impl Aggregator for DenseAllReduce {
    fn name(&self) -> String {
        "AllReduce-SGD".into()
    }

    fn allreduce_compatible(&self) -> bool {
        true
    }

    fn nominal_bits(&self) -> f64 {
        32.0
    }

    fn aggregate(&mut self, grads: &[&[f32]], ctx: &mut StepCtx, _rng: &mut Rng) -> Vec<f32> {
        let m = grads.len();
        let bufs: Vec<Vec<f32>> = grads.iter().map(|g| g.to_vec()).collect();
        let mut sum = ctx.allreduce_sum(bufs, 32.0);
        ctx.time_decode(|| crate::tensor::scale(1.0 / m as f32, &mut sum));
        sum
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{NetConfig, SimClock};
    use crate::util::quickcheck::{check, ensure_slice_close};

    #[test]
    fn prop_dense_is_exact_mean() {
        check("dense allreduce == mean", 100, |g| {
            let m = g.usize_in(1, 8);
            let n = g.size_scaled(1, 2000);
            let grads: Vec<Vec<f32>> = (0..m).map(|_| g.vec_normal(n, 1.0)).collect();
            let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
            let net = NetConfig::flat(m, 10.0);
            let mut clock = SimClock::default();
            let mut ctx = StepCtx::new(&net, &mut clock);
            let mut rng = Rng::new(0);
            let out = DenseAllReduce::new().aggregate(&refs, &mut ctx, &mut rng);
            let mean = crate::tensor::mean_of(&refs);
            ensure_slice_close(&out, &mean, 1e-5, "mean")
        });
    }
}
