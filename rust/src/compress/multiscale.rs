//! QSGDMaxNormMultiScale Quantization (paper §4.2, Algorithm 2).
//!
//! Extends the single-scale scheme with a *set* of scales: small-magnitude
//! coordinates are quantized at a larger scale (finer grid) while their
//! levels still fit the small scale's bit budget — eq. (10) guarantees
//! `a·s* <= s_min`. Coordinate scales differ across workers, so the paper's
//! *scale sharing* (elementwise min-all-reduce of the scale indices,
//! ceil(log2 N) bits/coord overhead) makes the scheme all-reduce compatible.

use crate::collectives::StepCtx;
use crate::util::rng::Rng;

use super::fused;
use super::kernels::{self, ScaleTable};
use super::Aggregator;

pub struct QsgdMultiScale {
    pub bits: Vec<usize>,
    /// sorted ascending levels per scale
    pub scales: Vec<usize>,
    /// precomputed padded scale tables (no per-call Vec<f32> builds)
    table: ScaleTable,
    packed: fused::PackedScratch,
    idx_scratch: Vec<Vec<u8>>,
    uniform: Vec<Vec<f32>>,
}

impl QsgdMultiScale {
    pub fn new(bits: &[usize]) -> anyhow::Result<QsgdMultiScale> {
        let sorted = kernels::sorted_scale_bits(bits)?;
        let scales: Vec<usize> = sorted.iter().map(|&b| kernels::s_for_bits(b)).collect();
        // levels are bounded by s_min + 1 (eq. 10), but the decode divides
        // by the *selected* scale; the sum bound that matters for widening
        // is M * (s_min + 1). Prove i32 safety at the largest scale anyway.
        fused::assert_widening_rule(scales[scales.len() - 1])?;
        let table = ScaleTable::new(&scales);
        Ok(QsgdMultiScale {
            bits: bits.to_vec(),
            scales,
            table,
            packed: fused::PackedScratch::new(),
            idx_scratch: Vec::new(),
            uniform: Vec::new(),
        })
    }

    /// Paper r = ceil(log s_min) + 1 + ceil(log N): level bits at the small
    /// scale plus sign plus the scale-index share.
    fn payload_bits(&self) -> f64 {
        kernels::bits_for_s(self.scales[0])
    }

    fn index_bits(&self) -> f64 {
        kernels::index_bits_for(self.scales.len())
    }
}

impl Aggregator for QsgdMultiScale {
    fn name(&self) -> String {
        format!("QSGD-MN-TS-({})", self.bits.iter().map(|b| b.to_string()).collect::<Vec<_>>().join(","))
    }

    fn allreduce_compatible(&self) -> bool {
        true
    }

    fn nominal_bits(&self) -> f64 {
        self.payload_bits() + self.index_bits()
    }

    fn aggregate(&mut self, grads: &[&[f32]], ctx: &mut StepCtx, rng: &mut Rng) -> Vec<f32> {
        let m = grads.len();
        let n = grads[0].len();
        assert!(m <= fused::MAX_WORKERS, "M={m} exceeds MAX_WORKERS");

        // 1. shared max norm (Algorithm 2 line 5)
        let norms: Vec<f32> = grads.iter().map(|g| kernels::l2_norm(g)).collect();
        let wnorm = ctx.allreduce_max_scalar(&norms);

        // 2. per-worker coordinate scales (line 6) — persistent pool
        let table = self.table;
        let idx_scratch = &mut self.idx_scratch;
        ctx.time_encode(|| fused::scale_index_into(grads, wnorm, &table, idx_scratch));

        // 3. scale sharing: elementwise min across workers (line 7),
        //    ceil(log2 N) bits per coordinate of overhead
        let shared_idx = ctx.allreduce_min_u8(&self.idx_scratch, self.index_bits());

        // 4. quantize at the shared scales (line 8) into packed biased
        //    codes (levels bounded by s_min + 1, eq. 10); 5. packed-resident
        //    sum all-reduce (line 9) through the schedule-generic data
        //    plane, chunk-pipelined with the encode; 6. single reconstruct
        //    from the exact integer sum (line 10).
        let payload_bits = self.payload_bits();
        let mut out = vec![0.0f32; n];
        fused::multiscale_step_packed(
            grads,
            wnorm,
            &table,
            &shared_idx,
            payload_bits,
            &mut self.packed,
            &mut self.uniform,
            ctx,
            rng,
            None,
            &mut out,
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{NetConfig, SimClock};
    use crate::util::quickcheck::{check, ensure, ensure_close};

    fn run(agg: &mut QsgdMultiScale, grads: &[Vec<f32>], seed: u64) -> (Vec<f32>, f64) {
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let net = NetConfig::flat(grads.len(), 10.0);
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut clock);
        let mut rng = Rng::new(seed);
        let out = agg.aggregate(&refs, &mut ctx, &mut rng);
        (out, clock.bits_per_worker)
    }

    #[test]
    fn wire_bits_match_paper_formula() {
        // 32 (norm) + d*ceil(log N) (scale share) + d*r (levels)
        let n = 1000;
        let grads: Vec<Vec<f32>> = (0..4).map(|w| vec![0.1 * (w as f32 + 1.0); n]).collect();
        let mut agg = QsgdMultiScale::new(&[2, 6]).unwrap();
        let (_, bits) = run(&mut agg, &grads, 7);
        // s_min = 1 -> 2-bit levels + 1-bit scale index share
        assert_eq!(bits, 32.0 + (n as f64) * 2.0 + (n as f64) * 1.0);
    }

    #[test]
    fn prop_scale_sharing_invariant() {
        // after sharing, every worker quantizes coordinate i at the same
        // scale, and the min rule picks the smallest proposed index.
        check("scale sharing = elementwise min", 60, |g| {
            let m = g.usize_in(2, 6);
            let n = g.size_scaled(1, 800);
            let scales = [7usize, 127];
            let grads: Vec<Vec<f32>> = (0..m).map(|_| g.vec_normal(n, 1.0)).collect();
            let wnorm = grads.iter().map(|v| kernels::l2_norm(v)).fold(0.0f32, f32::max);
            let mut per_worker: Vec<Vec<u8>> = Vec::new();
            for gr in &grads {
                let mut idx = vec![0u8; n];
                kernels::multiscale_scale_index(gr, wnorm, &scales, &mut idx);
                per_worker.push(idx);
            }
            let shared = crate::collectives::min_allreduce_u8(&per_worker);
            for i in 0..n {
                let want = per_worker.iter().map(|v| v[i]).min().unwrap();
                ensure(shared[i] == want, &format!("idx {i}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_unbiased_aggregate_statistical() {
        check("multiscale aggregate unbiased", 4, |g| {
            let m = 3;
            let n = 96;
            let grads: Vec<Vec<f32>> = (0..m).map(|_| g.vec_normal(n, 1.0)).collect();
            let mean =
                crate::tensor::mean_of(&grads.iter().map(|v| v.as_slice()).collect::<Vec<_>>());
            let mut agg = QsgdMultiScale::new(&[4, 8]).unwrap();
            let trials = 1500;
            let mut acc = vec![0.0f64; n];
            for t in 0..trials {
                let (out, _) = run(&mut agg, &grads, 50_000 + t as u64);
                for i in 0..n {
                    acc[i] += out[i] as f64;
                }
            }
            let wmax = grads.iter().map(|v| crate::tensor::norm2_f32(v)).fold(0.0f32, f32::max);
            let se = 4.0 * wmax as f64 / (7.0 * (trials as f64 * m as f64).sqrt());
            for i in 0..n {
                let est = acc[i] / trials as f64;
                ensure_close(est, mean[i] as f64, (se / 1.0f64.max(mean[i].abs() as f64)).max(1e-6), "unbiased")?;
            }
            Ok(())
        });
    }

    #[test]
    fn two_scale_beats_single_scale_error_same_bits() {
        // Fig 7/8 mechanism: (2,6) two-scale should have lower squared error
        // than plain 2-bit on the same gradient at (almost) the same bits.
        let mut g2 = QsgdMultiScale::new(&[2, 6]).unwrap();
        let mut q2 = super::super::qsgd_maxnorm::QsgdMaxNorm::new(2).unwrap();
        let mut rng = Rng::new(31);
        let n = 4096;
        let mut base = vec![0.0f32; n];
        rng.fill_normal_f32(&mut base, 1.0);
        let grads = vec![base.clone(), base.clone()];
        let (mut e_ts, mut e_ss) = (0.0f64, 0.0f64);
        for t in 0..200 {
            let (out_ts, _) = run(&mut g2, &grads, 900 + t);
            let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
            let net = NetConfig::flat(2, 10.0);
            let mut clock = SimClock::default();
            let mut ctx = StepCtx::new(&net, &mut clock);
            let mut r2 = Rng::new(900 + t);
            let out_ss = q2.aggregate(&refs, &mut ctx, &mut r2);
            for i in 0..n {
                e_ts += (out_ts[i] as f64 - base[i] as f64).powi(2);
                e_ss += (out_ss[i] as f64 - base[i] as f64).powi(2);
            }
        }
        assert!(
            e_ts < e_ss,
            "two-scale error {e_ts} must beat single-scale {e_ss}"
        );
    }

    #[test]
    fn rejects_bad_scale_sets() {
        assert!(QsgdMultiScale::new(&[4]).is_err());
        assert!(QsgdMultiScale::new(&[4, 4]).is_err());
        assert!(QsgdMultiScale::new(&[2, 6, 10]).is_ok());
    }
}
