//! QSGDMaxNorm Quantization (paper §4.1, Algorithm 1), integer domain.
//!
//! Protocol per step:
//! 1. max-all-reduce the per-worker L2 norms -> shared scale `||w||_2`;
//! 2. each worker stochastically quantizes against `||w||_2` at s levels,
//!    packing biased codes straight into the packed-resident operand on the
//!    persistent thread pool (chunk-pipelined with the reduce);
//! 3. one sum-all-reduce of the packed codes through the schedule-generic
//!    packed data plane (`collectives::PackedReduce`: fixed- or
//!    growing-width ring, tree, or naive — r = b bits/coord on the paper
//!    ledger, hop-accurate resident widths on the deployment ledger);
//! 4. a single decode of the reduced integer sum (eq. 8) — the all-reduce
//!    compatibility property: decode commutes with the sum.

use crate::collectives::StepCtx;
use crate::util::rng::Rng;

use super::fused;
use super::kernels;
use super::Aggregator;

pub struct QsgdMaxNorm {
    pub bits: usize,
    pub s: usize,
    /// reused per-step packed-plane scratch — zero steady-state alloc
    packed: fused::PackedScratch,
    uniform: Vec<Vec<f32>>,
}

impl QsgdMaxNorm {
    pub fn new(bits: usize) -> anyhow::Result<QsgdMaxNorm> {
        anyhow::ensure!((2..=16).contains(&bits), "qsgd bits must be in 2..=16, got {bits}");
        let s = kernels::s_for_bits(bits);
        // overflow impossible by construction up to fused::MAX_WORKERS
        fused::assert_widening_rule(s)?;
        Ok(QsgdMaxNorm {
            bits,
            s,
            packed: fused::PackedScratch::new(),
            uniform: Vec::new(),
        })
    }
}

impl Aggregator for QsgdMaxNorm {
    fn name(&self) -> String {
        format!("QSGD-MN-{}", self.bits)
    }

    fn allreduce_compatible(&self) -> bool {
        true
    }

    fn nominal_bits(&self) -> f64 {
        self.bits as f64
    }

    fn aggregate(&mut self, grads: &[&[f32]], ctx: &mut StepCtx, rng: &mut Rng) -> Vec<f32> {
        let m = grads.len();
        let n = grads[0].len();
        assert!(m <= fused::MAX_WORKERS, "M={m} exceeds MAX_WORKERS");

        // 1. shared max norm (Algorithm 1 line 5)
        let norms: Vec<f32> = grads.iter().map(|g| kernels::l2_norm(g)).collect();
        let wnorm = ctx.allreduce_max_scalar(&norms);

        // 2–4. per-worker stochastic quantization (line 6), compressed-
        // domain sum all-reduce (line 7), single reconstruct from the exact
        // integer sum (line 8). The resident reduce operand is the packed
        // biased codes for *every* schedule (ring fixed/growing, tree,
        // naive — resolved per step from the net config + width policy),
        // encode is chunk-pipelined with the reduce, and the wire is
        // charged hop-accurately at the widths the schedule ships.
        let s = self.s;
        let wire_bits = kernels::bits_for_s(s);
        let mut out = vec![0.0f32; n];
        fused::qsgd_step_packed(
            grads,
            wnorm,
            s,
            wire_bits,
            &mut self.packed,
            &mut self.uniform,
            ctx,
            rng,
            None,
            &mut out,
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{NetConfig, SimClock};
    use crate::util::quickcheck::{check, ensure, ensure_close};

    fn run(agg: &mut QsgdMaxNorm, grads: &[Vec<f32>], seed: u64) -> (Vec<f32>, f64) {
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let net = NetConfig::flat(grads.len(), 10.0);
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut clock);
        let mut rng = Rng::new(seed);
        let out = agg.aggregate(&refs, &mut ctx, &mut rng);
        (out, clock.bits_per_worker)
    }

    #[test]
    fn wire_bits_match_paper_formula() {
        // paper: 32 + d*r bits (norm share + payload)
        let n = 1000;
        let grads: Vec<Vec<f32>> = (0..4).map(|w| vec![0.1 * (w as f32 + 1.0); n]).collect();
        let mut agg = QsgdMaxNorm::new(8).unwrap();
        let (_, bits) = run(&mut agg, &grads, 7);
        assert_eq!(bits, 32.0 + (n as f64) * 8.0);
    }

    #[test]
    fn prop_unbiased_aggregate_statistical() {
        // mean over many steps approaches the true mean gradient
        check("qsgd aggregate unbiased", 5, |g| {
            let m = g.usize_in(2, 4);
            let n = 128;
            let grads: Vec<Vec<f32>> = (0..m).map(|_| g.vec_normal(n, 1.0)).collect();
            let mean = crate::tensor::mean_of(&grads.iter().map(|v| v.as_slice()).collect::<Vec<_>>());
            let mut agg = QsgdMaxNorm::new(4).unwrap();
            let trials = 1500;
            let mut acc = vec![0.0f64; n];
            for t in 0..trials {
                let (out, _) = run(&mut agg, &grads, 1000 + t as u64);
                for i in 0..n {
                    acc[i] += out[i] as f64;
                }
            }
            let wmax = grads.iter().map(|v| crate::tensor::norm2_f32(v)).fold(0.0f32, f32::max);
            let se = 4.0 * wmax as f64 / (7.0 * (trials as f64 * m as f64).sqrt());
            for i in 0..n {
                let est = acc[i] / trials as f64;
                ensure_close(est, mean[i] as f64, (se / 1.0f64.max(mean[i].abs() as f64)).max(1e-6), "unbiased mean")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_identical_grads_high_precision_near_exact() {
        // at 12 bits the quantization error per coordinate is <= w/(2047)
        check("high precision ~ exact", 30, |g| {
            let m = g.usize_in(1, 6);
            let n = g.size_scaled(1, 1000);
            let base = g.vec_normal(n, 1.0);
            let grads: Vec<Vec<f32>> = (0..m).map(|_| base.clone()).collect();
            let mut agg = QsgdMaxNorm::new(12).unwrap();
            let (out, _) = run(&mut agg, &grads, g.rng().next_u64());
            let w = crate::tensor::norm2_f32(&base);
            let tol = (w / 2047.0) * 1.01;
            for i in 0..n {
                ensure(
                    (out[i] - base[i]).abs() <= tol,
                    &format!("coord {i}: |{} - {}| > {tol}", out[i], base[i]),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_integer_domain_bit_identical_to_f32_reference() {
        // the tentpole contract at aggregator level: the widened-integer
        // pipeline must reproduce the legacy f32-level pipeline exactly.
        check("qsgd int aggregate == f32 reference", 40, |g| {
            let m = g.usize_in(1, 6);
            let bits = *g.pick(&[2usize, 4, 8, 12]);
            let n = g.size_scaled(1, 1500);
            let grads: Vec<Vec<f32>> = (0..m).map(|_| g.vec_normal(n, 1.0)).collect();
            let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
            let seed = g.rng().next_u64();

            let mut agg = QsgdMaxNorm::new(bits).unwrap();
            let (got, _) = run(&mut agg, &grads, seed);

            let wnorm = refs
                .iter()
                .map(|v| crate::compress::kernels::l2_norm(v))
                .fold(0.0f32, f32::max);
            let rng = Rng::new(seed);
            let want = crate::compress::fused::reference_qsgd_aggregate(&refs, wnorm, agg.s, &rng);
            ensure(got == want, "integer-domain output differs from f32 reference")
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let grads: Vec<Vec<f32>> = (0..3).map(|w| vec![0.3 * (w as f32 - 1.0); 500]).collect();
        let mut a = QsgdMaxNorm::new(4).unwrap();
        let mut b = QsgdMaxNorm::new(4).unwrap();
        let (x, _) = run(&mut a, &grads, 99);
        let (y, _) = run(&mut b, &grads, 99);
        assert_eq!(x, y);
    }

    #[test]
    fn zero_gradients_stay_zero() {
        let grads: Vec<Vec<f32>> = (0..4).map(|_| vec![0.0f32; 64]).collect();
        let mut agg = QsgdMaxNorm::new(4).unwrap();
        let (out, _) = run(&mut agg, &grads, 5);
        assert!(out.iter().all(|&x| x == 0.0));
    }
}
