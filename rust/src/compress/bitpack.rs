//! Bit-packing substrate: b-bit signed levels <-> dense u64 words.
//!
//! The paper (§6, Limitations) observes that PyTorch/NCCL only ship >=8-bit
//! tensors, so sub-byte quantizers waste wire. This module is the substrate
//! the paper wished it had: sign-magnitude codes packed back-to-back into
//! u64 words. Used (a) to measure true wire bytes, (b) by the fused
//! integer-domain hot path and the micro benches to show pack/unpack runs at
//! memory bandwidth (the paper's stated reason for skipping bit-packing was
//! its cost in Python — in Rust it is ~free).
//!
//! The packer works at word granularity: a `u128` staging register absorbs
//! codes (one shift+or each) and spills one whole `u64` word exactly when it
//! fills — no per-coordinate word-index arithmetic and no read-modify-write
//! memory traffic like the old per-bit-field loop. For bit widths dividing
//! 64 (2/4/8/16 — every power-of-two quantizer) a chunked fast path builds
//! each output word from a fixed shift chain. A property test pins both
//! paths bit-identical to the scalar reference.
//!
//! Code format per coordinate: `bits`-wide field, MSB = sign (1 = negative),
//! remaining `bits-1` = magnitude level. `bits` in 2..=16, levels must fit.

use crate::tensor::LevelInt;
use crate::util::simd::{self, Backend};

/// Packed payload: `bits` per code, `len` codes.
#[derive(Clone, Debug, PartialEq)]
pub struct Packed {
    pub bits: u32,
    pub len: usize,
    pub words: Vec<u64>,
}

impl Packed {
    /// Byte-exact wire cost: `ceil(len*bits/8)`. (Previously reported whole
    /// `u64` words, overstating small payloads by up to 7 bytes.)
    pub fn wire_bytes(&self) -> usize {
        wire_bytes_for(self.len, self.bits)
    }
}

/// Byte-exact wire cost of any `(len, bits)` payload: `ceil(len*bits/8)`.
/// The one formula the packed wire format, the sparsified all-gather
/// baselines, and `StepCtx`'s byte-exact ledger all share.
pub fn wire_bytes_for(len: usize, bits: u32) -> usize {
    (len * bits as usize).div_ceil(8)
}

/// Resident width of the packed-resident ring: the smallest code width that
/// holds the *biased* sum of `m` contributions whose levels are bounded by
/// `lmax` — codes live in `[0, 2*m*lmax]` (each contribution is stored as
/// `level + lmax`), so the width is `bitlen(2*m*lmax)`. This headroom is the
/// carry-safety condition of [`add_packed_codes`]: no per-field sum can
/// overflow its field, hence no carry can cross a code boundary.
pub fn packed_sum_bits(lmax: usize, m: usize) -> u32 {
    let max_code = 2u64 * (m as u64).max(1) * (lmax as u64).max(1);
    let w = 64 - max_code.leading_zeros();
    assert!(w <= 32, "packed sum width {w} > 32 (lmax={lmax}, m={m})");
    w.max(2)
}

/// Code-count period at which field boundaries re-align with `u64` word
/// boundaries: chunk starts that are multiples of this never share a word
/// with the previous chunk — the disjointness the pipelined encode relies on
/// to pack chunks concurrently into one resident buffer.
pub fn codes_per_word_period(bits: u32) -> usize {
    fn gcd(a: u64, b: u64) -> u64 {
        if b == 0 {
            a
        } else {
            gcd(b, a % b)
        }
    }
    (64 / gcd(bits as u64, 64)) as usize
}

// Satellite fix (ISSUE 10): these used to `debug_assert!` the range and
// then clamp with `mag.min(max_mag)` — so a release build silently
// *saturated* an overflowing magnitude and shipped a corrupted payload with
// no signal, violating the PR 7 loud-guard discipline. The widening rule
// (`packed_sum_bits` / quantizer level bounds) means an overflow here can
// only be a real bug upstream, so the reference path now asserts loudly in
// release too; a NaN level saturates the `as u64` cast to 0 < max_mag but
// trips the (debug) integrality check and the upstream NaN guards.
#[inline(always)]
fn f32_code(lv: f32, mag_bits: u32, max_mag: u64) -> u64 {
    debug_assert_eq!(lv.fract(), 0.0, "non-integer level {lv}");
    let neg = lv < 0.0;
    let mag = lv.abs() as u64;
    assert!(
        mag <= max_mag,
        "level {lv} overflows {}-bit code (silent saturation forbidden)",
        mag_bits + 1
    );
    ((neg as u64) << mag_bits) | mag
}

#[inline(always)]
fn int_code<T: LevelInt>(lv: T, mag_bits: u32, max_mag: u64) -> u64 {
    let v = lv.to_i64();
    let neg = v < 0;
    let mag = v.unsigned_abs();
    assert!(
        mag <= max_mag,
        "level {v} overflows {}-bit code (silent saturation forbidden)",
        mag_bits + 1
    );
    ((neg as u64) << mag_bits) | mag
}

#[inline(always)]
fn decode_code(code: u64, mag_bits: u32, mag_mask: u64) -> i64 {
    let mag = (code & mag_mask) as i64;
    let neg = code >> mag_bits != 0;
    if neg {
        -mag
    } else {
        mag
    }
}

/// `u64` words needed for `len` codes of `bits` each (public: the fused
/// pipelined path sizes its resident buffers with it).
pub fn words_for(len: usize, bits: u32) -> usize {
    (len as u64 * bits as u64).div_ceil(64) as usize
}

/// Word-level packing core over any code-producing closure indexed 0..n.
/// `codes` must emit values < 2^bits.
#[inline(always)]
fn pack_core(n: usize, bits: u32, words: &mut Vec<u64>, code_at: impl Fn(usize) -> u64) {
    words.clear();
    words.resize(words_for(n, bits), 0);
    if n == 0 {
        return;
    }
    if 64 % bits == 0 {
        // aligned fast path: every output word is a fixed shift chain over
        // `per` input codes — no carry between words.
        let per = (64 / bits) as usize;
        let full = n / per;
        for (w, slot) in words.iter_mut().enumerate().take(full) {
            let base = w * per;
            let mut x = 0u64;
            for j in 0..per {
                x |= code_at(base + j) << (j as u32 * bits);
            }
            *slot = x;
        }
        let mut x = 0u64;
        for (j, i) in (full * per..n).enumerate() {
            x |= code_at(i) << (j as u32 * bits);
        }
        if full * per < n {
            words[full] = x;
        }
    } else {
        // u128 staging register: absorb codes, spill a whole word when full.
        let mut acc: u128 = 0;
        let mut fill: u32 = 0;
        let mut w = 0usize;
        for i in 0..n {
            acc |= (code_at(i) as u128) << fill;
            fill += bits;
            if fill >= 64 {
                words[w] = acc as u64;
                w += 1;
                acc >>= 64;
                fill -= 64;
            }
        }
        if fill > 0 {
            words[w] = acc as u64;
        }
    }
}

/// Word-level unpacking core: calls `emit(i, code)` for codes 0..len.
/// Dispatches to the gather-based SIMD extraction when a vector backend is
/// active; the scalar aligned/staging paths below remain the pinned oracle
/// (and the whole path under `REPRO_FORCE_SCALAR`).
#[inline(always)]
fn unpack_core(p: &Packed, mut emit: impl FnMut(usize, u64)) {
    let bits = p.bits;
    let mask = (1u64 << bits) - 1;
    if p.len == 0 {
        return;
    }
    let bk = simd::active();
    if bk != Backend::Scalar && p.len >= 8 {
        unpack_codes_at_with_backend(bk, &p.words, bits, 0, p.len, emit);
        return;
    }
    if 64 % bits == 0 {
        let per = (64 / bits) as usize;
        let full = p.len / per;
        for (w, &word) in p.words.iter().enumerate().take(full) {
            let base = w * per;
            let mut x = word;
            for j in 0..per {
                emit(base + j, x & mask);
                x >>= bits;
            }
        }
        if full * per < p.len {
            let mut x = p.words[full];
            for i in full * per..p.len {
                emit(i, x & mask);
                x >>= bits;
            }
        }
    } else {
        let mut acc: u128 = 0;
        let mut fill: u32 = 0;
        let mut w = 0usize;
        for i in 0..p.len {
            if fill < bits {
                acc |= (p.words[w] as u128) << fill;
                w += 1;
                fill += 64;
            }
            emit(i, (acc as u64) & mask);
            acc >>= bits;
            fill -= bits;
        }
    }
}

/// Offset variant of [`pack_core`]: writes codes `0..n` into the bit range
/// `[start_bit, start_bit + n*bits)` of `words`, preserving every bit of
/// `words` outside that range (read-modify-write on the boundary words).
/// The same u128 staging register as [`pack_core`], seeded with the
/// boundary word's existing low bits.
#[inline(always)]
fn pack_core_at(
    words: &mut [u64],
    start_bit: usize,
    n: usize,
    bits: u32,
    code_at: impl Fn(usize) -> u64,
) {
    if n == 0 {
        return;
    }
    let mut w = start_bit / 64;
    let off = (start_bit % 64) as u32;
    // seed with the existing bits below the range so they survive the spill
    let mut acc: u128 = (words[w] & low_mask(off)) as u128;
    let mut fill: u32 = off;
    for i in 0..n {
        acc |= (code_at(i) as u128) << fill;
        fill += bits;
        if fill >= 64 {
            words[w] = acc as u64;
            w += 1;
            acc >>= 64;
            fill -= 64;
        }
    }
    if fill > 0 {
        // merge with the existing bits above the range (the next chunk's)
        words[w] = (acc as u64) | (words[w] & !low_mask(fill));
    }
}

/// Offset variant of [`unpack_core`]: emits the `len` codes stored in the
/// bit range starting at `start_bit`.
#[inline(always)]
fn unpack_core_at(
    words: &[u64],
    start_bit: usize,
    len: usize,
    bits: u32,
    mut emit: impl FnMut(usize, u64),
) {
    if len == 0 {
        return;
    }
    let mask = (1u64 << bits) - 1;
    let mut w = start_bit / 64;
    let off = (start_bit % 64) as u32;
    let mut acc: u128 = (words[w] as u128) >> off;
    let mut fill: u32 = 64 - off;
    w += 1;
    for i in 0..len {
        if fill < bits {
            acc |= (words[w] as u128) << fill;
            w += 1;
            fill += 64;
        }
        emit(i, (acc as u64) & mask);
        acc >>= bits;
        fill -= bits;
    }
}

/// Mask of the low `b` bits (`b` in 0..=64, shift-safe).
#[inline(always)]
fn low_mask(b: u32) -> u64 {
    if b >= 64 {
        !0
    } else {
        (1u64 << b) - 1
    }
}

/// Pack raw (already-encoded) codes into fields
/// `[code_off, code_off + codes.len())` of `words`. Codes must be < 2^bits.
/// Rides the runtime SIMD dispatch (aligned-width word builder); the scalar
/// staging loop remains the pinned fallback and handles every tail.
pub fn pack_codes_at(codes: &[u64], bits: u32, words: &mut [u64], code_off: usize) {
    pack_codes_at_backend(simd::active(), codes, bits, words, code_off)
}

/// Backend-explicit form of [`pack_codes_at`] (test/bench seam).
pub fn pack_codes_at_backend(bk: Backend, codes: &[u64], bits: u32, words: &mut [u64], code_off: usize) {
    let start_bit = code_off * bits as usize;
    let mut done = 0usize;
    // SIMD fast path: word-aligned start, width dividing 64 with >= 4 codes
    // per word — each output word is an independent shift/OR reduction.
    if bk != Backend::Scalar && 64 % bits == 0 && start_bit % 64 == 0 && 64 / bits >= 4 && codes.len() >= (64 / bits) as usize
    {
        let w0 = start_bit / 64;
        let nw = simd::pack_aligned_words(bk, codes, bits, &mut words[w0..]);
        done = nw * (64 / bits) as usize;
    }
    pack_core_at(words, start_bit + done * bits as usize, codes.len() - done, bits, |i| {
        codes[done + i]
    });
}

/// Unpack `out.len()` raw codes starting at field `code_off`. Rides the
/// runtime SIMD dispatch (gather-based field extraction at any offset and
/// width); the scalar staging loop finishes the buffer-edge tail.
pub fn unpack_codes_at(words: &[u64], bits: u32, code_off: usize, out: &mut [u64]) {
    unpack_codes_at_backend(simd::active(), words, bits, code_off, out)
}

/// Backend-explicit form of [`unpack_codes_at`] (test/bench seam).
pub fn unpack_codes_at_backend(bk: Backend, words: &[u64], bits: u32, code_off: usize, out: &mut [u64]) {
    let start_bit = code_off * bits as usize;
    let done = if bk != Backend::Scalar {
        simd::unpack_fields(bk, words, start_bit, bits, out)
    } else {
        0
    };
    unpack_core_at(words, start_bit + done * bits as usize, out.len() - done, bits, |i, c| {
        out[done + i] = c
    });
}

/// Closure form of [`unpack_codes_at`]: emits `(i, code)` for the `len`
/// fields starting at `code_off` — the zero-scratch decode entry the fused
/// pipelined path feeds its per-chunk reconstruct from. SIMD extracts codes
/// into a stack block, then `emit` runs on the exact same integer codes the
/// scalar staging loop would have produced.
pub fn unpack_codes_at_with(
    words: &[u64],
    bits: u32,
    code_off: usize,
    len: usize,
    emit: impl FnMut(usize, u64),
) {
    unpack_codes_at_with_backend(simd::active(), words, bits, code_off, len, emit)
}

/// Backend-explicit form of [`unpack_codes_at_with`] (test/bench seam).
pub fn unpack_codes_at_with_backend(
    bk: Backend,
    words: &[u64],
    bits: u32,
    code_off: usize,
    len: usize,
    mut emit: impl FnMut(usize, u64),
) {
    let start_bit = code_off * bits as usize;
    let mut done = 0usize;
    if bk != Backend::Scalar && len >= 8 {
        let mut buf = [0u64; 64];
        while done < len {
            let take = (len - done).min(64);
            let got = simd::unpack_fields(bk, words, start_bit + done * bits as usize, bits, &mut buf[..take]);
            if got == 0 {
                break;
            }
            for (k, &c) in buf.iter().enumerate().take(got) {
                emit(done + k, c);
            }
            done += got;
            if got < take {
                break;
            }
        }
    }
    if done < len {
        unpack_core_at(words, start_bit + done * bits as usize, len - done, bits, |i, c| {
            emit(done + i, c)
        });
    }
}

/// Pack biased codes `levels[i] + bias` (all non-negative by construction:
/// `bias >= |level|`) into fields `[code_off, code_off + levels.len())`.
/// The biased representation is what makes ring hops a field-wise *add*:
/// biases accumulate linearly with the number of contributions, so the
/// decoder subtracts `contributions * bias` once at the end.
pub fn pack_biased_int_at<T: LevelInt>(
    levels: &[T],
    bias: i64,
    bits: u32,
    words: &mut [u64],
    code_off: usize,
) {
    debug_assert!((2..=32).contains(&bits), "biased bits out of range: {bits}");
    let max_code = low_mask(bits) as i64;
    pack_core_at(words, code_off * bits as usize, levels.len(), bits, |i| {
        let code = levels[i].to_i64() + bias;
        // loud in release (satellite fix): an out-of-range biased code can
        // only be a real bug, and truncation here would corrupt neighbors.
        assert!(
            (0..=max_code).contains(&code),
            "biased code {code} out of {bits}-bit range (silent saturation forbidden)"
        );
        code as u64
    });
}

/// `i32` specialization of [`pack_biased_int_at`] — the fused packed
/// pipeline's encode-side entry. The level→biased-code materialization runs
/// on the SIMD backend (widening add with a lane-wise range check that
/// panics before any word is published); the word staging absorbs each
/// 64-code block through the same scalar `pack_core_at` engine, whose
/// `(acc, fill)` dependency is inherently serial (DESIGN.md). Bit-identical
/// to the generic path: codes are exact integers either way.
pub fn pack_biased_i32_at(levels: &[i32], bias: i64, bits: u32, words: &mut [u64], code_off: usize) {
    pack_biased_i32_at_backend(simd::active(), levels, bias, bits, words, code_off)
}

/// Backend-explicit form of [`pack_biased_i32_at`] (test/bench seam).
pub fn pack_biased_i32_at_backend(
    bk: Backend,
    levels: &[i32],
    bias: i64,
    bits: u32,
    words: &mut [u64],
    code_off: usize,
) {
    debug_assert!((2..=32).contains(&bits), "biased bits out of range: {bits}");
    let mut done = 0usize;
    if bk != Backend::Scalar && levels.len() >= 16 {
        let max_code = low_mask(bits);
        let mut buf = [0u64; 64];
        while done < levels.len() {
            let take = (levels.len() - done).min(64);
            let got = simd::biased_codes_i32(bk, &levels[done..done + take], bias, max_code, &mut buf[..take]);
            if got == 0 {
                break;
            }
            // consecutive blocks share boundary words; pack_core_at's
            // read-modify-write seeding makes sequential block packs exact
            // (the same mechanism the pipelined chunk encode relies on).
            pack_core_at(words, (code_off + done) * bits as usize, got, bits, |i| buf[i]);
            done += got;
            if got < take {
                break;
            }
        }
    }
    if done < levels.len() {
        pack_biased_int_at(&levels[done..], bias, bits, words, code_off + done);
    }
}

/// Unpack biased fields `[code_off, code_off + out.len())`, subtracting
/// `bias` (pass `contributions * per_contribution_bias` after a reduction).
pub fn unpack_biased_i64_at(words: &[u64], bits: u32, code_off: usize, bias: i64, out: &mut [i64]) {
    unpack_core_at(words, code_off * bits as usize, out.len(), bits, |i, c| {
        out[i] = c as i64 - bias;
    });
}

/// Whole-buffer biased pack into a fresh [`Packed`] (codes = level + bias).
pub fn pack_biased_int<T: LevelInt>(levels: &[T], bias: i64, bits: u32) -> Packed {
    let mut words = vec![0u64; words_for(levels.len(), bits)];
    pack_biased_int_at(levels, bias, bits, &mut words, 0);
    Packed { bits, len: levels.len(), words }
}

/// In-place field-wise add of `src`'s biased codes `[code_lo, code_hi)` into
/// the same fields of `dst` — the packed-resident ring's reduce kernel.
///
/// Works as one big-integer add-with-carry over the covered words, with the
/// out-of-range bits of the boundary `src` words masked off. Sound only
/// under the carry-safety condition established by [`packed_sum_bits`]:
/// every resulting field value stays `< 2^bits`, so no carry ever
/// propagates past a field's top bit — the word-level carries the adc chain
/// forwards are exactly the *intra*-field carries of codes straddling a
/// word boundary.
pub fn add_packed_codes(dst: &mut [u64], src: &[u64], bits: u32, code_lo: usize, code_hi: usize) {
    add_packed_codes_backend(simd::active(), dst, src, bits, code_lo, code_hi)
}

/// One adc step with the carry-independence simplification: under the
/// carry-safety condition, a carry-in of 1 only ripples within the field
/// straddling this word's low boundary — that field's in-word part has
/// headroom (its total sum < 2^bits), so the ripple can never reach bit 63.
/// The carry OUT of the word is therefore `c1` (from `dst + src`) alone,
/// independent of the carry IN — the property that lets the SIMD body
/// compute all four lane carries in parallel.
#[inline(always)]
fn adc_word(d: &mut u64, s: u64, carry: u64) -> u64 {
    let (a, c1) = d.overflowing_add(s);
    let (b, c2) = a.overflowing_add(carry);
    debug_assert!(!c2, "add_packed_codes: carry ripple escaped a straddling field");
    *d = b;
    c1 as u64
}

/// Backend-explicit form of [`add_packed_codes`] (test/bench seam). The
/// masked boundary words run scalar; the full middle words ride the
/// vectorized add (see `util::simd::add_words` for the soundness argument).
pub fn add_packed_codes_backend(
    bk: Backend,
    dst: &mut [u64],
    src: &[u64],
    bits: u32,
    code_lo: usize,
    code_hi: usize,
) {
    if code_hi <= code_lo {
        return;
    }
    let lo_bit = code_lo * bits as usize;
    let hi_bit = code_hi * bits as usize;
    let w0 = lo_bit / 64;
    let w1 = (hi_bit - 1) / 64;
    if w0 == w1 {
        let rem = hi_bit - w1 * 64;
        let s = src[w0] & !low_mask((lo_bit % 64) as u32) & low_mask(rem as u32);
        let carry = adc_word(&mut dst[w0], s, 0);
        debug_assert_eq!(carry, 0, "add_packed_codes: carry escaped the range (overflowed field)");
        return;
    }
    // first (low-masked) word
    let mut carry = adc_word(&mut dst[w0], src[w0] & !low_mask((lo_bit % 64) as u32), 0);
    // full middle words [w0+1, w1): SIMD prefix, scalar remainder
    let mut w = w0 + 1;
    if w < w1 && bk != Backend::Scalar {
        let (done, c) = simd::add_words(bk, &mut dst[w..w1], &src[w..w1], carry);
        if done > 0 {
            carry = c;
            w += done;
        }
    }
    while w < w1 {
        carry = adc_word(&mut dst[w], src[w], carry);
        w += 1;
    }
    // last (high-masked) word
    let rem = hi_bit - w1 * 64;
    carry = adc_word(&mut dst[w1], src[w1] & low_mask(rem as u32), carry);
    // the range's top field has headroom, so the chain cannot carry out
    debug_assert_eq!(carry, 0, "add_packed_codes: carry escaped the range (overflowed field)");
}

/// Copy `src`'s fields `[code_lo, code_hi)` into `dst` (boundary words
/// merged bit-exactly) — the packed-resident ring's all-gather kernel.
pub fn copy_packed_codes(dst: &mut [u64], src: &[u64], bits: u32, code_lo: usize, code_hi: usize) {
    if code_hi <= code_lo {
        return;
    }
    let lo_bit = code_lo * bits as usize;
    let hi_bit = code_hi * bits as usize;
    let w0 = lo_bit / 64;
    let w1 = (hi_bit - 1) / 64;
    for w in w0..=w1 {
        let mut mask = !0u64;
        if w == w0 {
            mask &= !low_mask((lo_bit % 64) as u32);
        }
        if w == w1 {
            mask &= low_mask((hi_bit - w * 64) as u32);
        }
        dst[w] = (dst[w] & !mask) | (src[w] & mask);
    }
}

/// Pack signed integer levels (carried as exact-integer f32, the legacy
/// quantizer output format) into `bits`-wide sign-magnitude codes.
///
/// Panics in debug if a magnitude does not fit — quantizer level bounds
/// guarantee it (|level| <= s = 2^(bits-1) - 1).
pub fn pack(levels: &[f32], bits: u32) -> Packed {
    assert!((2..=16).contains(&bits), "bits out of range: {bits}");
    let mag_bits = bits - 1;
    let max_mag = (1u64 << mag_bits) - 1;
    let mut words = Vec::new();
    pack_core(levels.len(), bits, &mut words, |i| f32_code(levels[i], mag_bits, max_mag));
    Packed { bits, len: levels.len(), words }
}

/// Integer-domain pack: levels straight from a widened [`LevelInt`] buffer.
pub fn pack_int<T: LevelInt>(levels: &[T], bits: u32) -> Packed {
    let mut words = Vec::new();
    pack_int_into(levels, bits, &mut words);
    Packed { bits, len: levels.len(), words }
}

/// Scratch-reusing integer pack: fills `words` (cleared first) so steady-state
/// steps allocate nothing.
pub fn pack_int_into<T: LevelInt>(levels: &[T], bits: u32, words: &mut Vec<u64>) {
    assert!((2..=16).contains(&bits), "bits out of range: {bits}");
    let mag_bits = bits - 1;
    let max_mag = (1u64 << mag_bits) - 1;
    pack_core(levels.len(), bits, words, |i| int_code(levels[i], mag_bits, max_mag));
}

/// Unpack back to signed f32 levels.
pub fn unpack(p: &Packed) -> Vec<f32> {
    let mag_bits = p.bits - 1;
    let mag_mask = (1u64 << mag_bits) - 1;
    let mut out = vec![0.0f32; p.len];
    unpack_core(p, |i, code| out[i] = decode_code(code, mag_bits, mag_mask) as f32);
    out
}

/// Unpack into a widened integer buffer (`out.len()` must equal `p.len`).
pub fn unpack_int_into<T: LevelInt>(p: &Packed, out: &mut [T]) {
    assert_eq!(out.len(), p.len, "unpack_int_into: length mismatch");
    let mag_bits = p.bits - 1;
    let mag_mask = (1u64 << mag_bits) - 1;
    unpack_core(p, |i, code| out[i] = T::from_level(decode_code(code, mag_bits, mag_mask) as f32));
}

/// The pre-word-level scalar reference (one coordinate, one bit-field at a
/// time). Kept public as the baseline the property tests pin the word-level
/// paths against and the micro benches measure the speedup over.
pub fn pack_scalar_reference(levels: &[f32], bits: u32) -> Packed {
    assert!((2..=16).contains(&bits), "bits out of range: {bits}");
    let mag_bits = bits - 1;
    let max_mag = (1u64 << mag_bits) - 1;
    let n = levels.len();
    let mut words = vec![0u64; words_for(n, bits)];

    let mut bitpos = 0u64;
    for &lv in levels {
        let code = f32_code(lv, mag_bits, max_mag);
        let w = (bitpos / 64) as usize;
        let off = (bitpos % 64) as u32;
        words[w] |= code << off;
        if off + bits > 64 {
            words[w + 1] |= code >> (64 - off);
        }
        bitpos += bits as u64;
    }
    Packed { bits, len: n, words }
}

/// Scalar reference unpack (see [`pack_scalar_reference`]).
pub fn unpack_scalar_reference(p: &Packed) -> Vec<f32> {
    let bits = p.bits;
    let mag_bits = bits - 1;
    let mask = (1u64 << bits) - 1;
    let mag_mask = (1u64 << mag_bits) - 1;
    let mut out = Vec::with_capacity(p.len);

    let mut bitpos = 0u64;
    for _ in 0..p.len {
        let w = (bitpos / 64) as usize;
        let off = (bitpos % 64) as u32;
        let mut code = p.words[w] >> off;
        if off + bits > 64 {
            code |= p.words[w + 1] << (64 - off);
        }
        code &= mask;
        let mag = (code & mag_mask) as f32;
        let neg = code >> mag_bits != 0;
        out.push(if neg { -mag } else { mag });
        bitpos += bits as u64;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::kernels::{qsgd_encode, s_for_bits};
    use crate::util::quickcheck::{check, ensure};

    fn random_levels(g: &mut crate::util::quickcheck::Gen, bits: u32, n: usize) -> Vec<f32> {
        let max_mag = (1i64 << (bits - 1)) - 1;
        (0..n)
            .map(|_| {
                let mag = g.rng().next_below((max_mag + 1) as u64) as f32;
                if g.bool() {
                    -mag
                } else {
                    mag
                }
            })
            .collect()
    }

    #[test]
    fn roundtrip_simple() {
        let levels = vec![0.0, 1.0, -1.0, 3.0, -3.0, 2.0, 0.0, -0.0];
        for bits in [3u32, 4, 8, 13] {
            let p = pack(&levels, bits);
            let back = unpack(&p);
            // -0.0 packs as +0 (sign-magnitude of zero); compare by value
            assert_eq!(levels.len(), back.len());
            for (a, b) in levels.iter().zip(&back) {
                assert_eq!(*a, *b, "bits={bits}");
            }
        }
    }

    #[test]
    fn prop_roundtrip_random_levels() {
        check("bitpack roundtrip", 200, |g| {
            let bits = g.usize_in(2, 16) as u32;
            let n = g.size_scaled(0, 5000);
            let levels = random_levels(g, bits, n);
            let p = pack(&levels, bits);
            let back = unpack(&p);
            for i in 0..n {
                if levels[i] != back[i] {
                    return Err(format!("idx {i}: {} vs {}", levels[i], back[i]));
                }
            }
            // byte-exact wire cost (satellite fix: no u64-word rounding)
            ensure(p.wire_bytes() == (n * bits as usize).div_ceil(8), "size")
        });
    }

    #[test]
    fn prop_word_level_bit_identical_to_scalar_reference() {
        // the tentpole contract: the rewritten pack/unpack must produce the
        // exact same words / levels as the old per-bit-field loop.
        check("word-level == scalar reference", 300, |g| {
            let bits = g.usize_in(2, 16) as u32;
            let n = g.size_scaled(0, 4000);
            let levels = random_levels(g, bits, n);
            let fast = pack(&levels, bits);
            let slow = pack_scalar_reference(&levels, bits);
            if fast != slow {
                return Err(format!("packed words differ at bits={bits} n={n}"));
            }
            let back_fast = unpack(&fast);
            let back_slow = unpack_scalar_reference(&slow);
            ensure(back_fast == back_slow, "unpacked levels differ")
        });
    }

    #[test]
    fn prop_int_pack_matches_f32_pack() {
        check("pack_int == pack(f32 levels)", 200, |g| {
            let bits = g.usize_in(2, 16) as u32;
            let n = g.size_scaled(0, 3000);
            let levels = random_levels(g, bits, n);
            let as_i32: Vec<i32> = levels.iter().map(|&x| x as i32).collect();
            let pf = pack(&levels, bits);
            let pi = pack_int(&as_i32, bits);
            if pf != pi {
                return Err(format!("f32 vs i32 pack differ at bits={bits}"));
            }
            let mut back = vec![0i32; n];
            unpack_int_into(&pi, &mut back);
            for i in 0..n {
                if back[i] != as_i32[i] {
                    return Err(format!("idx {i}: {} vs {}", back[i], as_i32[i]));
                }
            }
            // i16 round-trips identically when the levels fit
            if bits <= 16 {
                let as_i16: Vec<i16> = levels.iter().map(|&x| x as i16).collect();
                let p16 = pack_int(&as_i16, bits);
                if p16 != pf {
                    return Err("i16 pack differs".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_quantizer_output_always_fits() {
        // end-to-end: whatever qsgd_encode emits at b bits packs losslessly
        // into b-bit codes — the wire-format invariant of DESIGN.md §4.
        check("qsgd levels fit their bit width", 100, |g| {
            let bitsu = *g.pick(&[2usize, 4, 6, 8]);
            let s = s_for_bits(bitsu);
            let n = g.size_scaled(1, 3000);
            let v = g.vec_adversarial(n);
            let mut u = vec![0.0f32; n];
            g.rng().fill_uniform_f32(&mut u);
            let w = crate::tensor::norm2_f32(&v).max(1e-30) * g.f32_in(1.0, 2.0);
            let mut z = vec![0.0f32; n];
            qsgd_encode(&v, w, &u, s, &mut z);
            let p = pack(&z, bitsu as u32);
            let back = unpack(&p);
            for i in 0..n {
                if z[i] != back[i] {
                    return Err(format!("idx {i}: {} vs {}", z[i], back[i]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_offset_pack_unpack_roundtrip_preserves_neighbors() {
        // packing a segment at an arbitrary code offset must (a) round-trip
        // the segment and (b) leave every bit outside the segment untouched.
        check("offset pack/unpack + neighbor preservation", 200, |g| {
            let bits = g.usize_in(2, 32) as u32;
            let total = g.size_scaled(1, 800);
            let lo = g.usize_in(0, total - 1);
            let hi = g.usize_in(lo + 1, total);
            let mut words = vec![0u64; words_for(total, bits)];
            // background: fill every field with a random code
            let bg: Vec<u64> =
                (0..total).map(|_| g.rng().next_u64() & low_mask(bits)).collect();
            pack_codes_at(&bg, bits, &mut words, 0);
            // overwrite [lo, hi) with fresh codes
            let seg: Vec<u64> =
                (0..hi - lo).map(|_| g.rng().next_u64() & low_mask(bits)).collect();
            pack_codes_at(&seg, bits, &mut words, lo);
            // every field reads back as expected
            let mut all = vec![0u64; total];
            unpack_codes_at(&words, bits, 0, &mut all);
            for i in 0..total {
                let want = if i >= lo && i < hi { seg[i - lo] } else { bg[i] };
                if all[i] != want {
                    return Err(format!("field {i}: {} vs {want} (bits={bits} lo={lo} hi={hi})", all[i]));
                }
            }
            // offset unpack agrees with the full unpack
            let mut sub = vec![0u64; hi - lo];
            unpack_codes_at(&words, bits, lo, &mut sub);
            ensure(sub == seg, "offset unpack differs")
        });
    }

    #[test]
    fn prop_biased_pack_roundtrip_and_packed_add() {
        // add_packed_codes over a segment == field-wise integer addition,
        // and it must not disturb fields outside the segment.
        check("biased pack + in-place packed add", 200, |g| {
            let m = g.usize_in(1, 9);
            let lmax = *g.pick(&[1usize, 7, 127, 2047]);
            let bits = packed_sum_bits(lmax, m);
            let n = g.size_scaled(1, 600);
            let lo = g.usize_in(0, n - 1);
            let hi = g.usize_in(lo + 1, n);
            let bufs: Vec<Vec<i32>> = (0..m)
                .map(|_| {
                    (0..n)
                        .map(|_| g.rng().next_below(2 * lmax as u64 + 1) as i32 - lmax as i32)
                        .collect()
                })
                .collect();
            // accumulate workers 1.. into worker 0's packed buffer over [lo, hi)
            let mut dst = pack_biased_int(&bufs[0], lmax as i64, bits);
            for b in &bufs[1..] {
                let src = pack_biased_int(b, lmax as i64, bits);
                add_packed_codes(&mut dst.words, &src.words, bits, lo, hi);
            }
            let mut got = vec![0i64; n];
            // inside [lo, hi): m contributions (bias m*lmax); outside: 1
            unpack_biased_i64_at(&dst.words, bits, 0, 0, &mut got);
            for i in 0..n {
                let want: i64 = if i >= lo && i < hi {
                    bufs.iter().map(|b| b[i] as i64).sum::<i64>() + (m as i64) * lmax as i64
                } else {
                    bufs[0][i] as i64 + lmax as i64
                };
                if got[i] != want {
                    return Err(format!(
                        "field {i}: {} vs {want} (bits={bits} m={m} lmax={lmax} lo={lo} hi={hi})",
                        got[i]
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_copy_packed_codes_segment_exact() {
        check("copy_packed_codes", 150, |g| {
            let bits = g.usize_in(2, 32) as u32;
            let n = g.size_scaled(1, 500);
            let lo = g.usize_in(0, n - 1);
            let hi = g.usize_in(lo + 1, n);
            let a: Vec<u64> = (0..n).map(|_| g.rng().next_u64() & low_mask(bits)).collect();
            let b: Vec<u64> = (0..n).map(|_| g.rng().next_u64() & low_mask(bits)).collect();
            let mut pa = vec![0u64; words_for(n, bits)];
            let mut pb = vec![0u64; words_for(n, bits)];
            pack_codes_at(&a, bits, &mut pa, 0);
            pack_codes_at(&b, bits, &mut pb, 0);
            copy_packed_codes(&mut pa, &pb, bits, lo, hi);
            let mut out = vec![0u64; n];
            unpack_codes_at(&pa, bits, 0, &mut out);
            for i in 0..n {
                let want = if i >= lo && i < hi { b[i] } else { a[i] };
                if out[i] != want {
                    return Err(format!("field {i}: {} vs {want}", out[i]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn add_packed_codes_at_field_saturation() {
        // every code at the field maximum 2*lmax, summed to the full
        // m-contribution saturation 2*m*lmax — the exact carry-safety
        // boundary of packed_sum_bits. No carry may escape any field.
        for &(lmax, m) in &[(1usize, 2usize), (7, 9), (127, 64), (2047, 5)] {
            let bits = packed_sum_bits(lmax, m);
            let n = 131; // prime: fields straddle word boundaries for odd widths
            let sat = vec![lmax as i32; n]; // biased code = 2*lmax, the max
            let mut dst = pack_biased_int(&sat, lmax as i64, bits);
            let src = dst.clone();
            for _ in 1..m {
                add_packed_codes(&mut dst.words, &src.words, bits, 0, n);
            }
            let mut got = vec![0i64; n];
            unpack_biased_i64_at(&dst.words, bits, 0, 0, &mut got);
            let want = 2 * (m as i64) * lmax as i64; // == 2^bits - 1 or below
            assert!(got.iter().all(|&x| x == want), "lmax={lmax} m={m} bits={bits}");
            assert!(want < (1i64 << bits), "saturated sum must fit its field");
        }
    }

    #[test]
    fn add_packed_codes_at_widening_rule_boundary() {
        // M at the assert_widening_rule boundary (MAX_WORKERS=4096 at
        // s=32767, the 16-bit quantizer): the resident width is 28 bits
        // (64 % 28 != 0, so fields straddle words) and the saturated sum
        // 2*M*s is the largest code the plane can ever hold. Simulate the
        // last add of the reduction: a (M-1)-contribution saturated partial
        // plus one saturated contribution.
        let (lmax, m) = (32767usize, 4096usize);
        let bits = packed_sum_bits(lmax, m);
        assert_eq!(bits, 28);
        let n = 67;
        let partial = 2 * (m as u64 - 1) * lmax as u64;
        let one = 2 * lmax as u64;
        let mut dst = vec![0u64; words_for(n, bits)];
        let mut src = vec![0u64; words_for(n, bits)];
        pack_codes_at(&vec![partial; n], bits, &mut dst, 0);
        pack_codes_at(&vec![one; n], bits, &mut src, 0);
        add_packed_codes(&mut dst, &src, bits, 0, n);
        let mut got = vec![0u64; n];
        unpack_codes_at(&dst, bits, 0, &mut got);
        let want = 2 * (m as u64) * lmax as u64;
        assert!(got.iter().all(|&x| x == want));
        assert!(want < 1u64 << bits);
    }

    #[test]
    fn add_packed_codes_non_word_aligned_boundaries() {
        // segment boundaries that are not word-aligned, at widths where a
        // field straddles two words (the edges the growing schedule's
        // narrow wire segments newly exercise): adds confined to [lo, hi)
        // must carry correctly across the straddled words and leave the
        // neighbors bit-exact.
        for &bits in &[3u32, 5, 7, 11, 13, 28] {
            let n = 200;
            let mask = low_mask(bits);
            // dst fields hold the max addend-safe value: sum stays in field
            let a: Vec<u64> = (0..n).map(|i| (i as u64 * 0x9E37) & (mask >> 1)).collect();
            let b: Vec<u64> = (0..n).map(|i| (i as u64 * 0x85EB) & (mask >> 1)).collect();
            for &(lo, hi) in &[(1usize, 2usize), (5, 64), (63, 64), (7, 193), (0, 200)] {
                let mut pa = vec![0u64; words_for(n, bits)];
                let mut pb = vec![0u64; words_for(n, bits)];
                pack_codes_at(&a, bits, &mut pa, 0);
                pack_codes_at(&b, bits, &mut pb, 0);
                add_packed_codes(&mut pa, &pb, bits, lo, hi);
                let mut got = vec![0u64; n];
                unpack_codes_at(&pa, bits, 0, &mut got);
                for i in 0..n {
                    let want = if i >= lo && i < hi { a[i] + b[i] } else { a[i] };
                    assert_eq!(got[i], want, "bits={bits} lo={lo} hi={hi} field {i}");
                }
            }
        }
    }

    #[test]
    fn width_transition_repack_roundtrip() {
        // the growing ring's between-hop width transition: codes packed at
        // a narrow hop width w1, unpacked, and repacked at a wider width w2
        // (and at a non-zero, non-word-aligned offset) must survive
        // bit-exactly, without disturbing resident neighbors.
        for &(w1, w2) in &[(2u32, 3u32), (3, 4), (4, 6), (5, 12), (7, 28), (12, 13)] {
            let n = 150;
            let codes: Vec<u64> = (0..n).map(|i| (i as u64 * 0xC2B2) & low_mask(w1)).collect();
            let mut narrow = vec![0u64; words_for(n, w1)];
            pack_codes_at(&codes, w1, &mut narrow, 0);
            // resident buffer at w2 with a live background, repack at offset
            let total = n + 77;
            let off = 31; // 31 * w2 is word-misaligned for every w2 here
            let bg: Vec<u64> = (0..total).map(|i| (i as u64 * 0x1B87) & low_mask(w2)).collect();
            let mut resident = vec![0u64; words_for(total, w2)];
            pack_codes_at(&bg, w2, &mut resident, 0);
            let mut tmp = vec![0u64; n];
            unpack_codes_at(&narrow, w1, 0, &mut tmp);
            pack_codes_at(&tmp, w2, &mut resident, off);
            let mut got = vec![0u64; total];
            unpack_codes_at(&resident, w2, 0, &mut got);
            for i in 0..total {
                let want = if i >= off && i < off + n { codes[i - off] } else { bg[i] };
                assert_eq!(got[i], want, "w1={w1} w2={w2} field {i}");
            }
        }
    }

    #[test]
    fn sum_width_and_alignment_helpers() {
        // 4-bit quantizer (s=7), 16 workers: codes up to 224 -> 8 bits
        assert_eq!(packed_sum_bits(7, 16), 8);
        // 2-bit (s=1), 4 workers: codes up to 8 -> 4 bits
        assert_eq!(packed_sum_bits(1, 4), 4);
        // 8-bit (s=127), 64 workers: codes up to 16256 -> 14 bits
        assert_eq!(packed_sum_bits(127, 64), 14);
        assert_eq!(codes_per_word_period(8), 8);
        assert_eq!(codes_per_word_period(14), 32);
        assert_eq!(codes_per_word_period(32), 2);
        assert_eq!(codes_per_word_period(13), 64);
        assert_eq!(wire_bytes_for(100, 3), 38);
        assert_eq!(wire_bytes_for(0, 5), 0);
    }

    #[test]
    fn packed_size_math() {
        let p = pack(&vec![1.0f32; 100], 3);
        assert_eq!(p.len, 100);
        assert_eq!(p.words.len(), (300usize).div_ceil(64));
        // byte-exact wire cost: 300 bits -> 38 bytes (not 5 words * 8 = 40)
        assert_eq!(p.wire_bytes(), 38);
        let p8 = pack(&vec![1.0f32; 3], 8);
        assert_eq!(p8.wire_bytes(), 3);
        let empty = pack(&[], 5);
        assert_eq!(unpack(&empty).len(), 0);
        assert_eq!(empty.wire_bytes(), 0);
    }

    // ---- satellite 1: overflow must be loud in release builds too ----

    #[test]
    #[should_panic(expected = "silent saturation forbidden")]
    fn overflowing_f32_level_cannot_silently_roundtrip() {
        // regression (fails pre-fix in release, where the old debug_assert
        // compiled out and `mag.min(max_mag)` saturated 8 -> 7 silently):
        // a 4-bit code holds magnitudes 0..=7, so level 8 must panic.
        let _ = pack(&[1.0f32, -3.0, 8.0], 4);
    }

    #[test]
    #[should_panic(expected = "silent saturation forbidden")]
    fn overflowing_int_level_cannot_silently_roundtrip() {
        let _ = pack_int(&[-8i32], 4); // |-8| > 7 = 2^(4-1) - 1
    }

    #[test]
    #[should_panic(expected = "silent saturation forbidden")]
    fn overflowing_biased_code_is_loud() {
        // bias 7 at 4 bits: codes 0..=15; level 9+7 = 16 is out of range.
        let mut words = vec![0u64; words_for(4, 4)];
        pack_biased_int_at(&[0i32, 1, -2, 9], 7, 4, &mut words, 0);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn simd_biased_block_check_is_loud_too() {
        // the SIMD materialization's lane-wise range check must fire for
        // every backend (>= 16 levels so the vector path engages when
        // available; the scalar fallback funnels into the assert above).
        let levels: Vec<i32> = (0..64).map(|i| if i == 37 { 9 } else { 0 }).collect();
        let mut words = vec![0u64; words_for(64, 4)];
        pack_biased_i32_at(&levels, 7, 4, &mut words, 0);
    }

    #[test]
    fn max_magnitude_level_still_roundtrips() {
        // the widening-rule boundary itself stays legal: |level| == max_mag
        for bits in [2u32, 4, 9, 16] {
            let top = ((1i64 << (bits - 1)) - 1) as f32;
            let p = pack(&[top, -top, 0.0], bits);
            assert_eq!(unpack(&p), vec![top, -top, 0.0]);
        }
    }

    // ---- satellite 3: differential fuzz matrix, SIMD vs scalar ----

    #[test]
    fn simd_vs_scalar_full_width_and_tail_matrix() {
        // every wire width 2..=16 and every resident-ish width up to 32,
        // every tail length 0..=codes_per_word_period(bits), both packing
        // directions, all available backends — words and codes must be
        // bit-identical to the scalar path.
        let mut rng = crate::util::rng::Rng::new(0xB17_9AC8);
        for bk in simd::available() {
            for bits in (2u32..=16).chain([20, 28, 32]) {
                let period = codes_per_word_period(bits);
                for tail in 0..=period {
                    let n = 2 * period + tail;
                    let mask = low_mask(bits);
                    let codes: Vec<u64> = (0..n).map(|_| rng.next_u64() & mask).collect();
                    let mut w_ref = vec![0u64; words_for(n, bits)];
                    pack_codes_at_backend(simd::Backend::Scalar, &codes, bits, &mut w_ref, 0);
                    let mut w_bk = vec![0u64; words_for(n, bits)];
                    pack_codes_at_backend(bk, &codes, bits, &mut w_bk, 0);
                    assert_eq!(w_bk, w_ref, "{bk:?} pack bits={bits} tail={tail}");
                    let mut back_ref = vec![0u64; n];
                    unpack_codes_at_backend(simd::Backend::Scalar, &w_ref, bits, 0, &mut back_ref);
                    let mut back_bk = vec![0u64; n];
                    unpack_codes_at_backend(bk, &w_ref, bits, 0, &mut back_bk);
                    assert_eq!(back_bk, back_ref, "{bk:?} unpack bits={bits} tail={tail}");
                    assert_eq!(back_ref, codes);
                }
            }
        }
    }

    #[test]
    fn simd_vs_scalar_unaligned_offsets() {
        // unaligned pack_core_at/unpack offsets: every field offset within a
        // word period, with a live background that must survive bit-exactly.
        let mut rng = crate::util::rng::Rng::new(0x0FF5E7);
        for bk in simd::available() {
            for bits in [3u32, 5, 8, 11, 13, 16, 28] {
                let period = codes_per_word_period(bits);
                let total = 3 * period + 17;
                for off in [0usize, 1, period / 2 + 1, period - 1] {
                    let mask = low_mask(bits);
                    let bg: Vec<u64> = (0..total).map(|_| rng.next_u64() & mask).collect();
                    let n = total - off.max(1) - 5;
                    let seg: Vec<u64> = (0..n).map(|_| rng.next_u64() & mask).collect();
                    let mut w_ref = vec![0u64; words_for(total, bits)];
                    pack_codes_at_backend(simd::Backend::Scalar, &bg, bits, &mut w_ref, 0);
                    let mut w_bk = w_ref.clone();
                    pack_codes_at_backend(simd::Backend::Scalar, &seg, bits, &mut w_ref, off);
                    pack_codes_at_backend(bk, &seg, bits, &mut w_bk, off);
                    assert_eq!(w_bk, w_ref, "{bk:?} offset pack bits={bits} off={off}");
                    let mut sub_ref = vec![0u64; n];
                    let mut sub_bk = vec![0u64; n];
                    unpack_codes_at_backend(simd::Backend::Scalar, &w_ref, bits, off, &mut sub_ref);
                    unpack_codes_at_backend(bk, &w_ref, bits, off, &mut sub_bk);
                    assert_eq!(sub_bk, sub_ref, "{bk:?} offset unpack bits={bits} off={off}");
                    assert_eq!(sub_ref, seg);
                }
            }
        }
    }

    #[test]
    fn simd_vs_scalar_closure_unpack_and_biased_pack() {
        let mut rng = crate::util::rng::Rng::new(0xC105_0E);
        for bk in simd::available() {
            for &(lmax, m) in &[(7usize, 4usize), (127, 64), (2047, 5)] {
                let bits = packed_sum_bits(lmax, m);
                let n = 777;
                let levels: Vec<i32> =
                    (0..n).map(|_| rng.next_below(2 * lmax as u64 + 1) as i32 - lmax as i32).collect();
                let mut w_ref = vec![0u64; words_for(n + 13, bits)];
                let mut w_bk = w_ref.clone();
                pack_biased_int_at(&levels, lmax as i64, bits, &mut w_ref, 13);
                pack_biased_i32_at_backend(bk, &levels, lmax as i64, bits, &mut w_bk, 13);
                assert_eq!(w_bk, w_ref, "{bk:?} biased pack bits={bits}");
                let mut got_ref = Vec::new();
                let mut got_bk = Vec::new();
                unpack_codes_at_with_backend(simd::Backend::Scalar, &w_ref, bits, 13, n, |i, c| {
                    got_ref.push((i, c))
                });
                unpack_codes_at_with_backend(bk, &w_ref, bits, 13, n, |i, c| got_bk.push((i, c)));
                assert_eq!(got_bk, got_ref, "{bk:?} closure unpack bits={bits}");
            }
        }
    }

    #[test]
    fn simd_vs_scalar_packed_add_matrix() {
        // the hop-loop add across widths, segment boundaries and backends:
        // vectorized middle words + scalar boundaries == scalar adc chain.
        let mut rng = crate::util::rng::Rng::new(0xADD_CA4);
        for bk in simd::available() {
            for &bits in &[3u32, 5, 8, 13, 14, 28, 32] {
                let n = 700; // enough words that the SIMD middle engages
                let mask = low_mask(bits);
                let a: Vec<u64> = (0..n).map(|_| rng.next_u64() & (mask >> 1)).collect();
                let b: Vec<u64> = (0..n).map(|_| rng.next_u64() & (mask >> 1)).collect();
                for &(lo, hi) in &[(0usize, 700usize), (1, 699), (63, 641), (130, 131)] {
                    let mut p_ref = vec![0u64; words_for(n, bits)];
                    pack_codes_at_backend(simd::Backend::Scalar, &a, bits, &mut p_ref, 0);
                    let mut p_bk = p_ref.clone();
                    let mut q = vec![0u64; words_for(n, bits)];
                    pack_codes_at_backend(simd::Backend::Scalar, &b, bits, &mut q, 0);
                    add_packed_codes_backend(simd::Backend::Scalar, &mut p_ref, &q, bits, lo, hi);
                    add_packed_codes_backend(bk, &mut p_bk, &q, bits, lo, hi);
                    assert_eq!(p_bk, p_ref, "{bk:?} add bits={bits} lo={lo} hi={hi}");
                    let mut got = vec![0u64; n];
                    unpack_codes_at_backend(simd::Backend::Scalar, &p_ref, bits, 0, &mut got);
                    for i in 0..n {
                        let want = if i >= lo && i < hi { a[i] + b[i] } else { a[i] };
                        assert_eq!(got[i], want, "bits={bits} field {i}");
                    }
                }
            }
        }
    }
}
