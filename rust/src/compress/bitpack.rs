//! Bit-packing substrate: b-bit signed levels <-> dense u64 words.
//!
//! The paper (§6, Limitations) observes that PyTorch/NCCL only ship >=8-bit
//! tensors, so sub-byte quantizers waste wire. This module is the substrate
//! the paper wished it had: sign-magnitude codes packed back-to-back into
//! u64 words. Used (a) to measure true wire bytes, (b) by the micro benches
//! to show pack/unpack runs at memory bandwidth (the paper's stated reason
//! for skipping bit-packing was its cost in Python — in Rust it is ~free).
//!
//! Code format per coordinate: `bits`-wide field, MSB = sign (1 = negative),
//! remaining `bits-1` = magnitude level. `bits` in 2..=16, levels must fit.

/// Packed payload: `bits` per code, `len` codes.
#[derive(Clone, Debug, PartialEq)]
pub struct Packed {
    pub bits: u32,
    pub len: usize,
    pub words: Vec<u64>,
}

impl Packed {
    pub fn wire_bytes(&self) -> usize {
        // true wire cost: packed words
        self.words.len() * 8
    }
}

/// Pack signed integer levels (carried as exact-integer f32, the quantizer
/// output format) into `bits`-wide sign-magnitude codes.
///
/// Panics in debug if a magnitude does not fit — quantizer level bounds
/// guarantee it (|level| <= s = 2^(bits-1) - 1).
pub fn pack(levels: &[f32], bits: u32) -> Packed {
    assert!((2..=16).contains(&bits), "bits out of range: {bits}");
    let mag_bits = bits - 1;
    let max_mag = (1u64 << mag_bits) - 1;
    let n = levels.len();
    let total_bits = n as u64 * bits as u64;
    let mut words = vec![0u64; total_bits.div_ceil(64) as usize];

    let mut bitpos = 0u64;
    for &lv in levels {
        debug_assert_eq!(lv.fract(), 0.0, "non-integer level {lv}");
        let neg = lv < 0.0;
        let mag = lv.abs() as u64;
        debug_assert!(mag <= max_mag, "level {lv} overflows {bits}-bit code");
        let code = ((neg as u64) << mag_bits) | mag.min(max_mag);

        let w = (bitpos / 64) as usize;
        let off = (bitpos % 64) as u32;
        words[w] |= code << off;
        if off + bits > 64 {
            words[w + 1] |= code >> (64 - off);
        }
        bitpos += bits as u64;
    }
    Packed { bits, len: n, words }
}

/// Unpack back to signed f32 levels.
pub fn unpack(p: &Packed) -> Vec<f32> {
    let bits = p.bits;
    let mag_bits = bits - 1;
    let mask = (1u64 << bits) - 1;
    let mag_mask = (1u64 << mag_bits) - 1;
    let mut out = Vec::with_capacity(p.len);

    let mut bitpos = 0u64;
    for _ in 0..p.len {
        let w = (bitpos / 64) as usize;
        let off = (bitpos % 64) as u32;
        let mut code = p.words[w] >> off;
        if off + bits > 64 {
            code |= p.words[w + 1] << (64 - off);
        }
        code &= mask;
        let mag = (code & mag_mask) as f32;
        let neg = code >> mag_bits != 0;
        out.push(if neg { -mag } else { mag });
        bitpos += bits as u64;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::kernels::{qsgd_encode, s_for_bits};
    use crate::util::quickcheck::{check, ensure};

    #[test]
    fn roundtrip_simple() {
        let levels = vec![0.0, 1.0, -1.0, 3.0, -3.0, 2.0, 0.0, -0.0];
        for bits in [3u32, 4, 8, 13] {
            let p = pack(&levels, bits);
            let back = unpack(&p);
            // -0.0 packs as +0 (sign-magnitude of zero); compare by value
            assert_eq!(levels.len(), back.len());
            for (a, b) in levels.iter().zip(&back) {
                assert_eq!(*a, *b, "bits={bits}");
            }
        }
    }

    #[test]
    fn prop_roundtrip_random_levels() {
        check("bitpack roundtrip", 200, |g| {
            let bits = g.usize_in(2, 16) as u32;
            let max_mag = (1i64 << (bits - 1)) - 1;
            let n = g.size_scaled(0, 5000);
            let levels: Vec<f32> = (0..n)
                .map(|_| {
                    let mag = g.rng().next_below((max_mag + 1) as u64) as f32;
                    if g.bool() {
                        -mag
                    } else {
                        mag
                    }
                })
                .collect();
            let p = pack(&levels, bits);
            let back = unpack(&p);
            for i in 0..n {
                if levels[i] != back[i] {
                    return Err(format!("idx {i}: {} vs {}", levels[i], back[i]));
                }
            }
            ensure(p.wire_bytes() <= (n * bits as usize).div_ceil(64) * 8 + 8, "size")
        });
    }

    #[test]
    fn prop_quantizer_output_always_fits() {
        // end-to-end: whatever qsgd_encode emits at b bits packs losslessly
        // into b-bit codes — the wire-format invariant of DESIGN.md §4.
        check("qsgd levels fit their bit width", 100, |g| {
            let bitsu = *g.pick(&[2usize, 4, 6, 8]);
            let s = s_for_bits(bitsu);
            let n = g.size_scaled(1, 3000);
            let v = g.vec_adversarial(n);
            let mut u = vec![0.0f32; n];
            g.rng().fill_uniform_f32(&mut u);
            let w = crate::tensor::norm2_f32(&v).max(1e-30) * g.f32_in(1.0, 2.0);
            let mut z = vec![0.0f32; n];
            qsgd_encode(&v, w, &u, s, &mut z);
            let p = pack(&z, bitsu as u32);
            let back = unpack(&p);
            for i in 0..n {
                if z[i] != back[i] {
                    return Err(format!("idx {i}: {} vs {}", z[i], back[i]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn packed_size_math() {
        let p = pack(&vec![1.0f32; 100], 3);
        assert_eq!(p.len, 100);
        assert_eq!(p.words.len(), (300usize).div_ceil(64));
        let empty = pack(&[], 5);
        assert_eq!(unpack(&empty).len(), 0);
    }
}
