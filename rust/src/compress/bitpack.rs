//! Bit-packing substrate: b-bit signed levels <-> dense u64 words.
//!
//! The paper (§6, Limitations) observes that PyTorch/NCCL only ship >=8-bit
//! tensors, so sub-byte quantizers waste wire. This module is the substrate
//! the paper wished it had: sign-magnitude codes packed back-to-back into
//! u64 words. Used (a) to measure true wire bytes, (b) by the fused
//! integer-domain hot path and the micro benches to show pack/unpack runs at
//! memory bandwidth (the paper's stated reason for skipping bit-packing was
//! its cost in Python — in Rust it is ~free).
//!
//! The packer works at word granularity: a `u128` staging register absorbs
//! codes (one shift+or each) and spills one whole `u64` word exactly when it
//! fills — no per-coordinate word-index arithmetic and no read-modify-write
//! memory traffic like the old per-bit-field loop. For bit widths dividing
//! 64 (2/4/8/16 — every power-of-two quantizer) a chunked fast path builds
//! each output word from a fixed shift chain. A property test pins both
//! paths bit-identical to the scalar reference.
//!
//! Code format per coordinate: `bits`-wide field, MSB = sign (1 = negative),
//! remaining `bits-1` = magnitude level. `bits` in 2..=16, levels must fit.

use crate::tensor::LevelInt;

/// Packed payload: `bits` per code, `len` codes.
#[derive(Clone, Debug, PartialEq)]
pub struct Packed {
    pub bits: u32,
    pub len: usize,
    pub words: Vec<u64>,
}

impl Packed {
    /// Byte-exact wire cost: `ceil(len*bits/8)`. (Previously reported whole
    /// `u64` words, overstating small payloads by up to 7 bytes.)
    pub fn wire_bytes(&self) -> usize {
        (self.len * self.bits as usize).div_ceil(8)
    }
}

#[inline(always)]
fn f32_code(lv: f32, mag_bits: u32, max_mag: u64) -> u64 {
    debug_assert_eq!(lv.fract(), 0.0, "non-integer level {lv}");
    let neg = lv < 0.0;
    let mag = lv.abs() as u64;
    debug_assert!(mag <= max_mag, "level {lv} overflows {}-bit code", mag_bits + 1);
    ((neg as u64) << mag_bits) | mag.min(max_mag)
}

#[inline(always)]
fn int_code<T: LevelInt>(lv: T, mag_bits: u32, max_mag: u64) -> u64 {
    let v = lv.to_i64();
    let neg = v < 0;
    let mag = v.unsigned_abs();
    debug_assert!(mag <= max_mag, "level {v} overflows {}-bit code", mag_bits + 1);
    ((neg as u64) << mag_bits) | mag.min(max_mag)
}

#[inline(always)]
fn decode_code(code: u64, mag_bits: u32, mag_mask: u64) -> i64 {
    let mag = (code & mag_mask) as i64;
    let neg = code >> mag_bits != 0;
    if neg {
        -mag
    } else {
        mag
    }
}

fn words_for(len: usize, bits: u32) -> usize {
    (len as u64 * bits as u64).div_ceil(64) as usize
}

/// Word-level packing core over any code-producing closure indexed 0..n.
/// `codes` must emit values < 2^bits.
#[inline(always)]
fn pack_core(n: usize, bits: u32, words: &mut Vec<u64>, code_at: impl Fn(usize) -> u64) {
    words.clear();
    words.resize(words_for(n, bits), 0);
    if n == 0 {
        return;
    }
    if 64 % bits == 0 {
        // aligned fast path: every output word is a fixed shift chain over
        // `per` input codes — no carry between words.
        let per = (64 / bits) as usize;
        let full = n / per;
        for (w, slot) in words.iter_mut().enumerate().take(full) {
            let base = w * per;
            let mut x = 0u64;
            for j in 0..per {
                x |= code_at(base + j) << (j as u32 * bits);
            }
            *slot = x;
        }
        let mut x = 0u64;
        for (j, i) in (full * per..n).enumerate() {
            x |= code_at(i) << (j as u32 * bits);
        }
        if full * per < n {
            words[full] = x;
        }
    } else {
        // u128 staging register: absorb codes, spill a whole word when full.
        let mut acc: u128 = 0;
        let mut fill: u32 = 0;
        let mut w = 0usize;
        for i in 0..n {
            acc |= (code_at(i) as u128) << fill;
            fill += bits;
            if fill >= 64 {
                words[w] = acc as u64;
                w += 1;
                acc >>= 64;
                fill -= 64;
            }
        }
        if fill > 0 {
            words[w] = acc as u64;
        }
    }
}

/// Word-level unpacking core: calls `emit(i, code)` for codes 0..len.
#[inline(always)]
fn unpack_core(p: &Packed, mut emit: impl FnMut(usize, u64)) {
    let bits = p.bits;
    let mask = (1u64 << bits) - 1;
    if p.len == 0 {
        return;
    }
    if 64 % bits == 0 {
        let per = (64 / bits) as usize;
        let full = p.len / per;
        for (w, &word) in p.words.iter().enumerate().take(full) {
            let base = w * per;
            let mut x = word;
            for j in 0..per {
                emit(base + j, x & mask);
                x >>= bits;
            }
        }
        if full * per < p.len {
            let mut x = p.words[full];
            for i in full * per..p.len {
                emit(i, x & mask);
                x >>= bits;
            }
        }
    } else {
        let mut acc: u128 = 0;
        let mut fill: u32 = 0;
        let mut w = 0usize;
        for i in 0..p.len {
            if fill < bits {
                acc |= (p.words[w] as u128) << fill;
                w += 1;
                fill += 64;
            }
            emit(i, (acc as u64) & mask);
            acc >>= bits;
            fill -= bits;
        }
    }
}

/// Pack signed integer levels (carried as exact-integer f32, the legacy
/// quantizer output format) into `bits`-wide sign-magnitude codes.
///
/// Panics in debug if a magnitude does not fit — quantizer level bounds
/// guarantee it (|level| <= s = 2^(bits-1) - 1).
pub fn pack(levels: &[f32], bits: u32) -> Packed {
    assert!((2..=16).contains(&bits), "bits out of range: {bits}");
    let mag_bits = bits - 1;
    let max_mag = (1u64 << mag_bits) - 1;
    let mut words = Vec::new();
    pack_core(levels.len(), bits, &mut words, |i| f32_code(levels[i], mag_bits, max_mag));
    Packed { bits, len: levels.len(), words }
}

/// Integer-domain pack: levels straight from a widened [`LevelInt`] buffer.
pub fn pack_int<T: LevelInt>(levels: &[T], bits: u32) -> Packed {
    let mut words = Vec::new();
    pack_int_into(levels, bits, &mut words);
    Packed { bits, len: levels.len(), words }
}

/// Scratch-reusing integer pack: fills `words` (cleared first) so steady-state
/// steps allocate nothing.
pub fn pack_int_into<T: LevelInt>(levels: &[T], bits: u32, words: &mut Vec<u64>) {
    assert!((2..=16).contains(&bits), "bits out of range: {bits}");
    let mag_bits = bits - 1;
    let max_mag = (1u64 << mag_bits) - 1;
    pack_core(levels.len(), bits, words, |i| int_code(levels[i], mag_bits, max_mag));
}

/// Unpack back to signed f32 levels.
pub fn unpack(p: &Packed) -> Vec<f32> {
    let mag_bits = p.bits - 1;
    let mag_mask = (1u64 << mag_bits) - 1;
    let mut out = vec![0.0f32; p.len];
    unpack_core(p, |i, code| out[i] = decode_code(code, mag_bits, mag_mask) as f32);
    out
}

/// Unpack into a widened integer buffer (`out.len()` must equal `p.len`).
pub fn unpack_int_into<T: LevelInt>(p: &Packed, out: &mut [T]) {
    assert_eq!(out.len(), p.len, "unpack_int_into: length mismatch");
    let mag_bits = p.bits - 1;
    let mag_mask = (1u64 << mag_bits) - 1;
    unpack_core(p, |i, code| out[i] = T::from_level(decode_code(code, mag_bits, mag_mask) as f32));
}

/// The pre-word-level scalar reference (one coordinate, one bit-field at a
/// time). Kept public as the baseline the property tests pin the word-level
/// paths against and the micro benches measure the speedup over.
pub fn pack_scalar_reference(levels: &[f32], bits: u32) -> Packed {
    assert!((2..=16).contains(&bits), "bits out of range: {bits}");
    let mag_bits = bits - 1;
    let max_mag = (1u64 << mag_bits) - 1;
    let n = levels.len();
    let mut words = vec![0u64; words_for(n, bits)];

    let mut bitpos = 0u64;
    for &lv in levels {
        let code = f32_code(lv, mag_bits, max_mag);
        let w = (bitpos / 64) as usize;
        let off = (bitpos % 64) as u32;
        words[w] |= code << off;
        if off + bits > 64 {
            words[w + 1] |= code >> (64 - off);
        }
        bitpos += bits as u64;
    }
    Packed { bits, len: n, words }
}

/// Scalar reference unpack (see [`pack_scalar_reference`]).
pub fn unpack_scalar_reference(p: &Packed) -> Vec<f32> {
    let bits = p.bits;
    let mag_bits = bits - 1;
    let mask = (1u64 << bits) - 1;
    let mag_mask = (1u64 << mag_bits) - 1;
    let mut out = Vec::with_capacity(p.len);

    let mut bitpos = 0u64;
    for _ in 0..p.len {
        let w = (bitpos / 64) as usize;
        let off = (bitpos % 64) as u32;
        let mut code = p.words[w] >> off;
        if off + bits > 64 {
            code |= p.words[w + 1] << (64 - off);
        }
        code &= mask;
        let mag = (code & mag_mask) as f32;
        let neg = code >> mag_bits != 0;
        out.push(if neg { -mag } else { mag });
        bitpos += bits as u64;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::kernels::{qsgd_encode, s_for_bits};
    use crate::util::quickcheck::{check, ensure};

    fn random_levels(g: &mut crate::util::quickcheck::Gen, bits: u32, n: usize) -> Vec<f32> {
        let max_mag = (1i64 << (bits - 1)) - 1;
        (0..n)
            .map(|_| {
                let mag = g.rng().next_below((max_mag + 1) as u64) as f32;
                if g.bool() {
                    -mag
                } else {
                    mag
                }
            })
            .collect()
    }

    #[test]
    fn roundtrip_simple() {
        let levels = vec![0.0, 1.0, -1.0, 3.0, -3.0, 2.0, 0.0, -0.0];
        for bits in [3u32, 4, 8, 13] {
            let p = pack(&levels, bits);
            let back = unpack(&p);
            // -0.0 packs as +0 (sign-magnitude of zero); compare by value
            assert_eq!(levels.len(), back.len());
            for (a, b) in levels.iter().zip(&back) {
                assert_eq!(*a, *b, "bits={bits}");
            }
        }
    }

    #[test]
    fn prop_roundtrip_random_levels() {
        check("bitpack roundtrip", 200, |g| {
            let bits = g.usize_in(2, 16) as u32;
            let n = g.size_scaled(0, 5000);
            let levels = random_levels(g, bits, n);
            let p = pack(&levels, bits);
            let back = unpack(&p);
            for i in 0..n {
                if levels[i] != back[i] {
                    return Err(format!("idx {i}: {} vs {}", levels[i], back[i]));
                }
            }
            // byte-exact wire cost (satellite fix: no u64-word rounding)
            ensure(p.wire_bytes() == (n * bits as usize).div_ceil(8), "size")
        });
    }

    #[test]
    fn prop_word_level_bit_identical_to_scalar_reference() {
        // the tentpole contract: the rewritten pack/unpack must produce the
        // exact same words / levels as the old per-bit-field loop.
        check("word-level == scalar reference", 300, |g| {
            let bits = g.usize_in(2, 16) as u32;
            let n = g.size_scaled(0, 4000);
            let levels = random_levels(g, bits, n);
            let fast = pack(&levels, bits);
            let slow = pack_scalar_reference(&levels, bits);
            if fast != slow {
                return Err(format!("packed words differ at bits={bits} n={n}"));
            }
            let back_fast = unpack(&fast);
            let back_slow = unpack_scalar_reference(&slow);
            ensure(back_fast == back_slow, "unpacked levels differ")
        });
    }

    #[test]
    fn prop_int_pack_matches_f32_pack() {
        check("pack_int == pack(f32 levels)", 200, |g| {
            let bits = g.usize_in(2, 16) as u32;
            let n = g.size_scaled(0, 3000);
            let levels = random_levels(g, bits, n);
            let as_i32: Vec<i32> = levels.iter().map(|&x| x as i32).collect();
            let pf = pack(&levels, bits);
            let pi = pack_int(&as_i32, bits);
            if pf != pi {
                return Err(format!("f32 vs i32 pack differ at bits={bits}"));
            }
            let mut back = vec![0i32; n];
            unpack_int_into(&pi, &mut back);
            for i in 0..n {
                if back[i] != as_i32[i] {
                    return Err(format!("idx {i}: {} vs {}", back[i], as_i32[i]));
                }
            }
            // i16 round-trips identically when the levels fit
            if bits <= 16 {
                let as_i16: Vec<i16> = levels.iter().map(|&x| x as i16).collect();
                let p16 = pack_int(&as_i16, bits);
                if p16 != pf {
                    return Err("i16 pack differs".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_quantizer_output_always_fits() {
        // end-to-end: whatever qsgd_encode emits at b bits packs losslessly
        // into b-bit codes — the wire-format invariant of DESIGN.md §4.
        check("qsgd levels fit their bit width", 100, |g| {
            let bitsu = *g.pick(&[2usize, 4, 6, 8]);
            let s = s_for_bits(bitsu);
            let n = g.size_scaled(1, 3000);
            let v = g.vec_adversarial(n);
            let mut u = vec![0.0f32; n];
            g.rng().fill_uniform_f32(&mut u);
            let w = crate::tensor::norm2_f32(&v).max(1e-30) * g.f32_in(1.0, 2.0);
            let mut z = vec![0.0f32; n];
            qsgd_encode(&v, w, &u, s, &mut z);
            let p = pack(&z, bitsu as u32);
            let back = unpack(&p);
            for i in 0..n {
                if z[i] != back[i] {
                    return Err(format!("idx {i}: {} vs {}", z[i], back[i]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn packed_size_math() {
        let p = pack(&vec![1.0f32; 100], 3);
        assert_eq!(p.len, 100);
        assert_eq!(p.words.len(), (300usize).div_ceil(64));
        // byte-exact wire cost: 300 bits -> 38 bytes (not 5 words * 8 = 40)
        assert_eq!(p.wire_bytes(), 38);
        let p8 = pack(&vec![1.0f32; 3], 8);
        assert_eq!(p8.wire_bytes(), 3);
        let empty = pack(&[], 5);
        assert_eq!(unpack(&empty).len(), 0);
        assert_eq!(empty.wire_bytes(), 0);
    }
}
