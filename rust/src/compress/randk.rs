//! GlobalRandK sparsified compressors (paper §4.3 / §4.4).
//!
//! All workers draw the SAME K coordinates from a shared per-step seed
//! ("Global" — this is what keeps the scheme all-reduce compatible: the
//! dense K-vectors align across workers), then apply QSGDMaxNorm or the
//! multi-scale quantizer to the gathered sub-vector.
//!
//! Reconstruction scatters the decoded values back *literally* (the
//! paper's variant): the estimator is K/n-shrunk — a randomized
//! block-coordinate update — which matches the paper's observed behaviour
//! (sparsified methods train stably but lag late in training, Figs
//! 5/6/9/10). Setting `rescale = true` switches to the n/K-rescaled
//! *unbiased* estimator; at the paper's K/n ≈ 1/2000 that variant has
//! ~2000× the variance and needs a proportionally smaller lr
//! (see DESIGN.md §2).

use crate::collectives::StepCtx;
use crate::util::rng::Rng;
use crate::util::threads;

use super::fused;
use super::kernels::{self, ScaleTable};
use super::Aggregator;

/// Shared-seed coordinate draw: every worker derives the same stream.
/// Returned indices are **sorted ascending** (`sample_distinct` sorts) —
/// the property the bucketed control plane relies on to route the drawn
/// coordinates to contiguous per-bucket slices of the gathered K-vector.
/// `pub(crate)`: [`crate::control`] must reproduce this exact draw for its
/// monolithic bit-identity pin.
pub(crate) fn shared_indices(rng: &Rng, n: usize, k: usize) -> Vec<usize> {
    let mut idx_rng = rng.derive(&[0x6B6579]); // "key"
    idx_rng.sample_distinct(n, k)
}

fn gather(v: &[f32], idx: &[usize], out: &mut Vec<f32>) {
    out.clear();
    out.extend(idx.iter().map(|&i| v[i]));
}

/// Parallel per-worker gather of the shared K coordinates into reusable
/// dense scratch (persistent pool — gathers are random-access bound).
/// `pub(crate)`: the bucketed control plane ([`crate::control`]) gathers
/// the same global K-set before routing coordinates to their buckets.
pub(crate) fn gather_all(grads: &[&[f32]], idx: &[usize], dense: &mut Vec<Vec<f32>>) {
    let m = grads.len();
    dense.resize_with(m, Vec::new);
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(m);
    for (d, g) in dense.iter_mut().zip(grads) {
        tasks.push(Box::new(move || gather(g, idx, d)));
    }
    threads::pool().scope_run(tasks);
}

pub struct GlobalRandK {
    pub bits: usize,
    pub s: usize,
    pub k: usize,
    pub n: usize,
    pub rescale: bool,
    dense: Vec<Vec<f32>>,
    packed: fused::PackedScratch,
    uniform: Vec<Vec<f32>>,
}

impl GlobalRandK {
    pub fn new(bits: usize, k: usize, n: usize) -> anyhow::Result<GlobalRandK> {
        anyhow::ensure!(k >= 1 && k <= n, "K must be in 1..=n (K={k}, n={n})");
        let s = kernels::s_for_bits(bits);
        fused::assert_widening_rule(s)?;
        Ok(GlobalRandK {
            bits,
            s,
            k,
            n,
            rescale: false,
            dense: Vec::new(),
            packed: fused::PackedScratch::new(),
            uniform: Vec::new(),
        })
    }
}

impl Aggregator for GlobalRandK {
    fn name(&self) -> String {
        format!("GRandK-MN-{}", self.bits)
    }

    fn allreduce_compatible(&self) -> bool {
        true
    }

    fn nominal_bits(&self) -> f64 {
        // payload is K coords of r bits: amortized over n coordinates
        self.bits as f64 * self.k as f64 / self.n as f64
    }

    fn aggregate(&mut self, grads: &[&[f32]], ctx: &mut StepCtx, rng: &mut Rng) -> Vec<f32> {
        let m = grads.len();
        let n = grads[0].len();
        debug_assert_eq!(n, self.n);
        assert!(m <= fused::MAX_WORKERS, "M={m} exceeds MAX_WORKERS");

        // shared coordinate draw (no wire cost: shared seed)
        let idx = shared_indices(rng, n, self.k);

        // gather sub-vectors; norms are over the gathered K-vector
        let dense = &mut self.dense;
        ctx.time_encode(|| gather_all(grads, &idx, dense));
        let norms: Vec<f32> = self.dense.iter().map(|d| kernels::l2_norm(d)).collect();
        let wnorm = ctx.allreduce_max_scalar(&norms);

        // QSGDMaxNorm on the K-vector: packed-resident pipelined path on
        // the gathered sub-vector, whatever the schedule
        let s = self.s;
        let wire_bits = kernels::bits_for_s(s);
        let dense_refs: Vec<&[f32]> = self.dense.iter().map(|d| d.as_slice()).collect();
        let rescale = if self.rescale { n as f32 / self.k as f32 } else { 1.0 };
        let mut sub = vec![0.0f32; self.k];
        fused::qsgd_step_packed(
            &dense_refs,
            wnorm,
            s,
            wire_bits,
            &mut self.packed,
            &mut self.uniform,
            ctx,
            rng,
            None,
            &mut sub,
        );

        // scatter back (+ n/K unbiasedness rescale)
        let mut out = vec![0.0f32; n];
        ctx.time_decode(|| {
            for (j, &i) in idx.iter().enumerate() {
                out[i] = sub[j] * rescale;
            }
        });
        out
    }
}

/// §4.4: GlobalRandK + the multi-scale quantizer on the gathered K-vector.
pub struct GlobalRandKMultiScale {
    pub bits: Vec<usize>,
    pub scales: Vec<usize>,
    pub k: usize,
    pub n: usize,
    pub rescale: bool,
    table: ScaleTable,
    dense: Vec<Vec<f32>>,
    packed: fused::PackedScratch,
    idx_scratch: Vec<Vec<u8>>,
    uniform: Vec<Vec<f32>>,
}

impl GlobalRandKMultiScale {
    pub fn new(bits: &[usize], k: usize, n: usize) -> anyhow::Result<GlobalRandKMultiScale> {
        anyhow::ensure!(k >= 1 && k <= n, "K must be in 1..=n (K={k}, n={n})");
        let sorted = kernels::sorted_scale_bits(bits)?;
        let scales: Vec<usize> = sorted.iter().map(|&b| kernels::s_for_bits(b)).collect();
        fused::assert_widening_rule(scales[scales.len() - 1])?;
        let table = ScaleTable::new(&scales);
        Ok(GlobalRandKMultiScale {
            bits: bits.to_vec(),
            scales,
            table,
            k,
            n,
            rescale: false,
            dense: Vec::new(),
            packed: fused::PackedScratch::new(),
            idx_scratch: Vec::new(),
            uniform: Vec::new(),
        })
    }

    fn index_bits(&self) -> f64 {
        kernels::index_bits_for(self.scales.len())
    }
}

impl Aggregator for GlobalRandKMultiScale {
    fn name(&self) -> String {
        format!(
            "GRandK-MN-TS-({})",
            self.bits.iter().map(|b| b.to_string()).collect::<Vec<_>>().join(",")
        )
    }

    fn allreduce_compatible(&self) -> bool {
        true
    }

    fn nominal_bits(&self) -> f64 {
        (kernels::bits_for_s(self.scales[0]) + self.index_bits()) * self.k as f64 / self.n as f64
    }

    fn aggregate(&mut self, grads: &[&[f32]], ctx: &mut StepCtx, rng: &mut Rng) -> Vec<f32> {
        let m = grads.len();
        let n = grads[0].len();
        assert!(m <= fused::MAX_WORKERS, "M={m} exceeds MAX_WORKERS");

        let idx = shared_indices(rng, n, self.k);

        let dense = &mut self.dense;
        ctx.time_encode(|| gather_all(grads, &idx, dense));
        let norms: Vec<f32> = self.dense.iter().map(|d| kernels::l2_norm(d)).collect();
        let wnorm = ctx.allreduce_max_scalar(&norms);

        // per-worker scale proposal + scale sharing on the K-vector
        let table = self.table;
        let dense_refs: Vec<&[f32]> = self.dense.iter().map(|d| d.as_slice()).collect();
        let idx_scratch = &mut self.idx_scratch;
        ctx.time_encode(|| fused::scale_index_into(&dense_refs, wnorm, &table, idx_scratch));
        let shared_scale_idx = ctx.allreduce_min_u8(&self.idx_scratch, self.index_bits());

        // multi-scale encode into packed biased codes + packed-resident sum
        // all-reduce (levels bounded by s_min + 1), schedule-generic
        let payload_bits = kernels::bits_for_s(self.scales[0]);
        let rescale = if self.rescale { n as f32 / self.k as f32 } else { 1.0 };
        let mut sub = vec![0.0f32; self.k];
        fused::multiscale_step_packed(
            &dense_refs,
            wnorm,
            &table,
            &shared_scale_idx,
            payload_bits,
            &mut self.packed,
            &mut self.uniform,
            ctx,
            rng,
            None,
            &mut sub,
        );

        let mut out = vec![0.0f32; n];
        ctx.time_decode(|| {
            for (j, &i) in idx.iter().enumerate() {
                out[i] = sub[j] * rescale;
            }
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{NetConfig, SimClock};
    use crate::util::quickcheck::{check, ensure, ensure_close};

    fn run(agg: &mut dyn Aggregator, grads: &[Vec<f32>], seed: u64) -> (Vec<f32>, f64) {
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let net = NetConfig::flat(grads.len(), 10.0);
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut clock);
        let mut rng = Rng::new(seed);
        let out = agg.aggregate(&refs, &mut ctx, &mut rng);
        (out, clock.bits_per_worker)
    }

    #[test]
    fn prop_sparsity_pattern_is_shared_and_k_sized() {
        check("randk output support == K shared coords", 60, |g| {
            let n = g.size_scaled(32, 3000);
            let k = g.usize_in(1, n / 2);
            let m = g.usize_in(2, 5);
            let grads: Vec<Vec<f32>> =
                (0..m).map(|_| g.vec_f32(n, 0.5, 2.0)).collect(); // strictly nonzero
            let mut agg = GlobalRandK::new(4, k, n).unwrap();
            let (out, _) = run(&mut agg, &grads, g.rng().next_u64());
            let nz = out.iter().filter(|x| **x != 0.0).count();
            ensure(nz <= k, &format!("support {nz} > K {k}"))
        });
    }

    #[test]
    fn prop_unbiased_with_rescale() {
        // E[aggregate] = mean gradient, over both index and rounding draws
        check("grandk unbiased", 3, |g| {
            let n = 64;
            let k = 16;
            let m = 2;
            let grads: Vec<Vec<f32>> = (0..m).map(|_| g.vec_normal(n, 1.0)).collect();
            let mean =
                crate::tensor::mean_of(&grads.iter().map(|v| v.as_slice()).collect::<Vec<_>>());
            let mut agg = GlobalRandK::new(8, k, n).unwrap();
            agg.rescale = true; // the unbiased estimator variant
            let trials = 6000;
            let mut acc = vec![0.0f64; n];
            for t in 0..trials {
                let (out, _) = run(&mut agg, &grads, 777 + t as u64);
                for i in 0..n {
                    acc[i] += out[i] as f64;
                }
            }
            // dominant variance: the n/K rescaled Bernoulli selection
            let gmax = grads
                .iter()
                .flat_map(|v| v.iter())
                .fold(0.0f32, |a, b| a.max(b.abs())) as f64;
            let se = 4.0 * gmax * ((n as f64 / k as f64) / (trials as f64).sqrt());
            for i in 0..n {
                let est = acc[i] / trials as f64;
                ensure_close(est, mean[i] as f64, (se / 1.0f64.max(mean[i].abs() as f64)).max(1e-6), "unbiased")?;
            }
            Ok(())
        });
    }

    #[test]
    fn multiscale_variant_shares_support_with_single_scale() {
        // same seed => same coordinate draw for both variants
        let n = 500;
        let k = 50;
        let grads: Vec<Vec<f32>> = (0..3).map(|w| vec![0.1 + w as f32 * 0.01; n]).collect();
        let mut a = GlobalRandK::new(4, k, n).unwrap();
        let mut b = GlobalRandKMultiScale::new(&[4, 8], k, n).unwrap();
        let (xa, _) = run(&mut a, &grads, 4242);
        let (xb, _) = run(&mut b, &grads, 4242);
        let sup_a: Vec<usize> = xa.iter().enumerate().filter(|(_, v)| **v != 0.0).map(|(i, _)| i).collect();
        let sup_b: Vec<usize> = xb.iter().enumerate().filter(|(_, v)| **v != 0.0).map(|(i, _)| i).collect();
        assert_eq!(sup_a, sup_b);
    }

    #[test]
    fn wire_bits_are_k_scaled() {
        let n = 10_000;
        let k = 100;
        let grads: Vec<Vec<f32>> = (0..4).map(|_| vec![0.5f32; n]).collect();
        let mut agg = GlobalRandK::new(8, k, n).unwrap();
        let (_, bits) = run(&mut agg, &grads, 1);
        assert_eq!(bits, 32.0 + (k as f64) * 8.0);
        let mut agg_ts = GlobalRandKMultiScale::new(&[8, 12], k, n).unwrap();
        let (_, bits_ts) = run(&mut agg_ts, &grads, 1);
        // scale-index share is byte-exact: 100 coords at 1 bit -> 13 bytes
        let idx_bits = (8 * crate::compress::bitpack::wire_bytes_for(k, 1)) as f64;
        assert_eq!(bits_ts, 32.0 + (k as f64) * 8.0 + idx_bits);
    }

    #[test]
    fn k_bounds_validated() {
        assert!(GlobalRandK::new(4, 0, 10).is_err());
        assert!(GlobalRandK::new(4, 11, 10).is_err());
        assert!(GlobalRandKMultiScale::new(&[4, 8], 5, 10).is_ok());
    }
}
