//! SignSGD with majority vote (Bernstein et al.) — 1-bit baseline.
//!
//! Each worker transmits sign bits; the vote is a sum of ±1 which *could*
//! ride an all-reduce, but the published scheme (and [30]'s bit-packed
//! implementation the paper cites) exchanges the packed sign tensors via
//! all-gather — we follow that, so SignSGD pays the O(M) gather cost in the
//! scalability analysis, matching its classification as non-linear in [16].

use crate::collectives::StepCtx;
use crate::util::rng::Rng;

use super::kernels::sign;
use super::Aggregator;

pub struct SignSgdMajority;

impl SignSgdMajority {
    pub fn new() -> SignSgdMajority {
        SignSgdMajority
    }
}

impl Default for SignSgdMajority {
    fn default() -> Self {
        Self::new()
    }
}

impl Aggregator for SignSgdMajority {
    fn name(&self) -> String {
        "SignSGD-MV".into()
    }

    fn allreduce_compatible(&self) -> bool {
        false
    }

    fn nominal_bits(&self) -> f64 {
        1.0
    }

    fn aggregate(&mut self, grads: &[&[f32]], ctx: &mut StepCtx, _rng: &mut Rng) -> Vec<f32> {
        let n = grads[0].len();
        // encode: sign vectors (conceptually bit-packed; wire charged
        // byte-exactly as the packed payload, ceil(n*1/8) bytes per rank)
        let signs: Vec<Vec<f32>> = ctx.time_encode(|| {
            grads
                .iter()
                .map(|g| g.iter().map(|&v| sign(v)).collect())
                .collect()
        });
        ctx.charge_allgather(n as f64, 1.0);
        // majority vote, decoded once per worker
        ctx.time_decode(|| {
            let mut out = vec![0.0f32; n];
            for s in &signs {
                crate::tensor::add_assign(&mut out, s);
            }
            for o in out.iter_mut() {
                *o = sign(*o);
            }
            out
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{NetConfig, SimClock};
    use crate::util::quickcheck::{check, ensure};

    fn run(grads: &[Vec<f32>]) -> (Vec<f32>, f64) {
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let net = NetConfig::flat(grads.len(), 10.0);
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut clock);
        let mut rng = Rng::new(0);
        let out = SignSgdMajority::new().aggregate(&refs, &mut ctx, &mut rng);
        (out, clock.bits_per_worker)
    }

    #[test]
    fn majority_vote_basic() {
        let grads = vec![
            vec![1.0, -1.0, 2.0, 0.0],
            vec![3.0, -2.0, -1.0, 0.0],
            vec![-1.0, -3.0, 4.0, 0.0],
        ];
        let (out, bits) = run(&grads);
        assert_eq!(out, vec![1.0, -1.0, 1.0, 0.0]);
        // byte-exact packed wire: 4 sign bits -> 1 byte -> 8 ledger bits
        assert_eq!(bits, 8.0);
    }

    #[test]
    fn wire_bytes_are_byte_exact() {
        // ceil(n/8) bytes per rank, not fractional bits (satellite fix)
        for n in [1usize, 7, 8, 9, 1000, 1001] {
            let grads: Vec<Vec<f32>> = (0..3).map(|_| vec![1.0f32; n]).collect();
            let (_, bits) = run(&grads);
            let want = (8 * crate::compress::bitpack::wire_bytes_for(n, 1)) as f64;
            assert_eq!(bits, want, "n={n}");
        }
    }

    #[test]
    fn prop_output_is_sign_valued() {
        check("signsgd output in {-1,0,1}", 80, |g| {
            let m = g.usize_in(1, 7);
            let n = g.size_scaled(1, 1500);
            let grads: Vec<Vec<f32>> = (0..m).map(|_| g.vec_adversarial(n)).collect();
            let (out, _) = run(&grads);
            for (i, &o) in out.iter().enumerate() {
                ensure(
                    o == 1.0 || o == -1.0 || o == 0.0,
                    &format!("idx {i}: {o}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn unanimous_sign_always_wins() {
        check_unanimous();
    }

    fn check_unanimous() {
        let grads: Vec<Vec<f32>> = (0..5).map(|w| vec![0.1 + w as f32; 32]).collect();
        let (out, _) = run(&grads);
        assert!(out.iter().all(|&o| o == 1.0));
    }
}
