//! Scalar quantization math — the Rust mirror of `python/compile/kernels/ref.py`.
//!
//! Every function here computes the exact f32 operation sequence of the
//! Pallas kernels (same op order: `a = |v|/w`, `scaled = a*s`, `l = floor`,
//! `p = scaled - l`, `level = l + 1{u < p}`), so the hot path is
//! bit-for-bit identical to the lowered HLO — asserted by
//! `rust/tests/pallas_parity.rs` (DESIGN.md §5).

use crate::tensor::LevelInt;
use crate::util::simd::{self, Backend};

/// Stack block size for SIMD level materialization in the integer-domain
/// encoders: the vector kernel fills f32 levels into this scratch, then the
/// same `T::from_level` cast as the scalar loop lands them in the widened
/// integer buffer — one code path for the lossless cast, minimal unsafe.
const LEVEL_BLOCK: usize = 256;

/// jnp.sign semantics: 0 for 0 (f32::signum would give ±1 for ±0).
#[inline(always)]
pub fn sign(v: f32) -> f32 {
    if v > 0.0 {
        1.0
    } else if v < 0.0 {
        -1.0
    } else {
        0.0
    }
}

/// Paper bit-width -> number of non-zero levels: b bits leave b-1 bits for
/// the magnitude level, so `s = 2^(b-1) - 1` (r = ceil(log s) + 1 = b).
pub fn s_for_bits(bits: usize) -> usize {
    assert!((2..=16).contains(&bits), "bits out of range: {bits}");
    (1usize << (bits - 1)) - 1
}

/// Wire bits per coordinate for s levels: the magnitude takes values
/// 0..=s (s+1 of them), plus the sign bit — ceil(log2(s+1)) + 1.
/// (The paper writes r = ceil(log s) + 1, which coincides for s = 2^k - 1,
/// the only values the bit-width mapping produces.)
pub fn bits_for_s(s: usize) -> f64 {
    ((s + 1) as f64).log2().ceil() + 1.0
}

/// One coordinate of eq. (6)/(7): the signed integer level.
///
/// Branchless (perf pass, EXPERIMENTS.md §Perf): the stochastic-rounding
/// comparison `u < p` is a coin flip — as a branch it mispredicts ~50% and
/// costs ~20 cycles/coord; as an arithmetic select the loop vectorizes.
/// The float op ORDER is identical to the Pallas kernel (|v|/w, *s, floor,
/// compare), preserving the bit-exactness contract of DESIGN.md §5.
#[inline(always)]
pub fn qsgd_level(v: f32, safe_w: f32, u: f32, s: f32) -> f32 {
    let a = v.abs() / safe_w;
    let scaled = a * s;
    let l = scaled.floor();
    let p = scaled - l;
    let level = l + (u < p) as u32 as f32;
    let sg = ((v > 0.0) as i32 - (v < 0.0) as i32) as f32;
    sg * level
}

/// Vectorized QSGDMaxNorm encode: fills `out[i] = zeta_i`.
/// `wnorm` is the shared max norm; `u` the explicit uniform randomness.
/// Dispatches to the runtime-detected SIMD backend; the scalar tail (and the
/// whole buffer under `REPRO_FORCE_SCALAR`) runs the pinned reference loop.
pub fn qsgd_encode(v: &[f32], wnorm: f32, u: &[f32], s: usize, out: &mut [f32]) {
    qsgd_encode_backend(simd::active(), v, wnorm, u, s, out)
}

/// Backend-explicit form of [`qsgd_encode`] — the test/bench seam that lets
/// one process exercise both the vector path and the scalar oracle.
pub fn qsgd_encode_backend(bk: Backend, v: &[f32], wnorm: f32, u: &[f32], s: usize, out: &mut [f32]) {
    debug_assert_eq!(v.len(), u.len());
    debug_assert_eq!(v.len(), out.len());
    if wnorm <= 0.0 {
        out.fill(0.0);
        return;
    }
    let sf = s as f32;
    let done = simd::qsgd_levels(bk, v, wnorm, u, sf, out);
    for i in done..v.len() {
        out[i] = qsgd_level(v[i], wnorm, u[i], sf);
    }
}

/// Integer-domain QSGDMaxNorm encode: identical float op order to
/// [`qsgd_encode`], but the exact-integer level lands directly in a widened
/// integer buffer — the 8×/16× narrower all-reduce operand of the fused hot
/// path (DESIGN.md §Performance). Bit-identical to the f32 path by
/// construction: the level value is the same f32 before the lossless cast.
pub fn qsgd_encode_int<T: LevelInt>(v: &[f32], wnorm: f32, u: &[f32], s: usize, out: &mut [T]) {
    qsgd_encode_int_backend(simd::active(), v, wnorm, u, s, out)
}

/// Backend-explicit form of [`qsgd_encode_int`]. The SIMD kernel fills f32
/// levels into a stack block; the levels then go through the *same*
/// `T::from_level` lossless cast as the scalar loop, so the integer output
/// is bit-identical whichever backend ran.
pub fn qsgd_encode_int_backend<T: LevelInt>(
    bk: Backend,
    v: &[f32],
    wnorm: f32,
    u: &[f32],
    s: usize,
    out: &mut [T],
) {
    debug_assert_eq!(v.len(), u.len());
    debug_assert_eq!(v.len(), out.len());
    debug_assert!((s as i64) <= T::MAX_MAG, "s={s} overflows {}", T::TAG);
    if wnorm <= 0.0 {
        out.fill(T::default());
        return;
    }
    let sf = s as f32;
    let mut done = 0usize;
    if bk != Backend::Scalar {
        let mut block = [0.0f32; LEVEL_BLOCK];
        while done < v.len() {
            let take = (v.len() - done).min(LEVEL_BLOCK);
            let got =
                simd::qsgd_levels(bk, &v[done..done + take], wnorm, &u[done..done + take], sf, &mut block[..take]);
            if got == 0 {
                break;
            }
            for k in 0..got {
                out[done + k] = T::from_level(block[k]);
            }
            done += got;
            if got < take {
                break;
            }
        }
    }
    for i in done..v.len() {
        out[i] = T::from_level(qsgd_level(v[i], wnorm, u[i], sf));
    }
}

/// Decode an all-reduced level sum into the averaged gradient (eq. 8, /M).
pub fn qsgd_decode_sum(zeta_sum: &mut [f32], wnorm: f32, s: usize, m: usize) {
    let k = wnorm / (s as f32 * m as f32);
    for z in zeta_sum.iter_mut() {
        *z *= k;
    }
}

/// eq. (8) from an integer level sum. Mirrors [`qsgd_decode_sum`]'s float
/// ops exactly (`sum * k`), so the output is bit-identical to the f32-level
/// path whenever that path's f32 sum was itself exact — i.e. `m*s < 2^24`
/// (e.g. any `bits <= 12` at <= 4096 workers, or 16-bit at <= 512). Beyond
/// that the widening rule still guarantees the *integer* sum is exact while
/// the legacy f32 sum would have rounded: the paths diverge and the integer
/// result is the correct one.
pub fn qsgd_decode_sum_int<T: LevelInt>(
    sum: &[T],
    wnorm: f32,
    s: usize,
    m: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(sum.len(), out.len());
    let k = wnorm / (s as f32 * m as f32);
    for (o, &z) in out.iter_mut().zip(sum) {
        *o = z.to_f32() * k;
    }
}

/// Validate a multi-scale bit set and return it sorted ascending: at
/// least 2 scales, at most [`MAX_SCALES`], every width in 2..=16, all
/// distinct. Shared by the monolithic TS aggregators and the bucketed
/// control plane so the two paths can never drift on what a legal set is
/// (their bit-identity is test-pinned). Distinctness is checked on the
/// widths, which is equivalent to distinctness of the s-values
/// ([`s_for_bits`] is strictly monotonic).
pub fn sorted_scale_bits(bits: &[usize]) -> anyhow::Result<Vec<usize>> {
    anyhow::ensure!(bits.len() >= 2, "multi-scale needs >= 2 scales");
    anyhow::ensure!(
        bits.len() <= MAX_SCALES,
        "multi-scale supports at most {MAX_SCALES} scales"
    );
    let mut sorted = bits.to_vec();
    sorted.sort_unstable();
    anyhow::ensure!(
        sorted.iter().all(|b| (2..=16).contains(b)),
        "multi-scale bits must be in 2..=16"
    );
    anyhow::ensure!(
        sorted.windows(2).all(|w| w[0] < w[1]),
        "scales must be distinct"
    );
    Ok(sorted)
}

/// Scale-share overhead per coordinate for an `num_scales`-scale set:
/// `ceil(log2 N)`, floored at 1 bit (the paper's r includes the share even
/// for the two-scale set). Shared by the multi-scale aggregators and the
/// bucketed control plane so every path charges the same overhead.
pub fn index_bits_for(num_scales: usize) -> f64 {
    (num_scales as f64).log2().ceil().max(1.0)
}

/// Cap on the number of scales in a multi-scale set. The paper uses 2–3;
/// eight covers any plausible sweep while keeping the per-coordinate select
/// a fixed-trip-count (fully unrollable) loop.
pub const MAX_SCALES: usize = 8;

/// Precomputed scale tables for the multi-scale kernels.
///
/// The previous kernels rebuilt a `Vec<f32>` of casted scales on *every
/// call* (per worker, per step). This table is built once per aggregator:
/// `qual` is padded with `+inf` so the qualifying-count compare is false for
/// padding lanes, `sel` with `0.0` so the branchless select accumulates
/// nothing there — both loops run a fixed `MAX_SCALES` trip count that LLVM
/// unrolls and vectorizes.
#[derive(Clone, Copy, Debug)]
pub struct ScaleTable {
    qual: [f32; MAX_SCALES],
    sel: [f32; MAX_SCALES],
    len: usize,
    /// smallest scale (the wire-format bit budget)
    pub smin: f32,
}

impl ScaleTable {
    pub fn new(scales: &[usize]) -> ScaleTable {
        assert!(
            !scales.is_empty() && scales.len() <= MAX_SCALES,
            "scale set size {} not in 1..={MAX_SCALES}",
            scales.len()
        );
        assert!(scales.windows(2).all(|w| w[0] < w[1]), "scales must be sorted");
        let mut qual = [f32::INFINITY; MAX_SCALES];
        let mut sel = [0.0f32; MAX_SCALES];
        for (i, &s) in scales.iter().enumerate() {
            qual[i] = s as f32;
            sel[i] = s as f32;
        }
        ScaleTable { qual, sel, len: scales.len(), smin: scales[0] as f32 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Branchless select of scale `idx`: sum of `(idx==j) * s_j` over the
    /// padded table — the same compare chain the Pallas kernel lowers to.
    ///
    /// NOTE: any `idx >= len()` lands in a `0.0` padding lane and selects
    /// 0.0. That is fine on the *encode* side, where indices are produced
    /// internally by [`multiscale_scale_index_t`] and always in range — but
    /// a decode must never feed this a wire-derived index directly: a
    /// corrupted scale share would flow into the `/ s` of eq. (12) as a
    /// divide-by-zero and emerge as silent ±inf gradients. Decode
    /// boundaries use [`Self::select_checked`].
    #[inline(always)]
    pub fn select(&self, idx: u32) -> f32 {
        let mut s_eff = 0.0f32;
        for j in 0..MAX_SCALES {
            s_eff += (idx == j as u32) as u32 as f32 * self.sel[j];
        }
        s_eff
    }

    /// [`Self::select`] with a loud release-mode range check — the decode-
    /// boundary entry. A poisoned or out-of-range scale-share index panics
    /// here instead of producing a non-finite gradient unnoticed.
    #[inline(always)]
    pub fn select_checked(&self, idx: u32) -> f32 {
        assert!(
            (idx as usize) < self.len,
            "scale index {idx} out of range (table has {} scales) — corrupt scale share",
            self.len
        );
        self.select(idx)
    }

    /// The padded select lanes (`0.0` padding) — handed to the SIMD select
    /// chain and to differential tests.
    pub fn sel_lanes(&self) -> &[f32; MAX_SCALES] {
        &self.sel
    }

    /// The padded qualifying lanes (`+inf` padding) — handed to the SIMD
    /// scale-index kernel and to differential tests.
    pub fn qual_lanes(&self) -> &[f32; MAX_SCALES] {
        &self.qual
    }
}

/// eq. (10): per-coordinate scale index (largest qualifying scale).
/// `scales` must be sorted ascending; returns indices in 0..N as u8.
pub fn multiscale_scale_index(v: &[f32], wnorm: f32, scales: &[usize], out: &mut [u8]) {
    multiscale_scale_index_t(v, wnorm, &ScaleTable::new(scales), out)
}

/// Table-based form of [`multiscale_scale_index`] — the zero-allocation
/// hot-path entry used by the aggregators.
pub fn multiscale_scale_index_t(v: &[f32], wnorm: f32, table: &ScaleTable, out: &mut [u8]) {
    multiscale_scale_index_t_backend(simd::active(), v, wnorm, table, out)
}

/// Backend-explicit form of [`multiscale_scale_index_t`].
pub fn multiscale_scale_index_t_backend(bk: Backend, v: &[f32], wnorm: f32, table: &ScaleTable, out: &mut [u8]) {
    debug_assert_eq!(v.len(), out.len());
    let safe_w = if wnorm > 0.0 { wnorm } else { 1.0 };
    let thresh = safe_w * table.smin;
    // `s·|v| <= thresh` is monotone decreasing in s, so the qualifying
    // scales are a prefix of the sorted set: the selected index is
    // (count of qualifying scales) − 1. Branchless popcount-style select —
    // index 0 always qualifies since |v| <= ||w||. Padding lanes hold +inf
    // (inf·|v| > thresh, and inf·0 = NaN compares false), contributing 0.
    let done = simd::scale_index(bk, v, thresh, &table.qual, out);
    for (o, &vi) in out.iter_mut().zip(v).skip(done) {
        let av = vi.abs();
        let mut count = 0u32;
        for j in 0..MAX_SCALES {
            count += (table.qual[j] * av <= thresh) as u32;
        }
        *o = (count.max(1) - 1) as u8;
    }
}

/// eq. (9)/(11): stochastic rounding at the shared per-coordinate scale.
pub fn multiscale_encode(
    v: &[f32],
    wnorm: f32,
    u: &[f32],
    scale_idx: &[u8],
    scales: &[usize],
    out: &mut [f32],
) {
    multiscale_encode_t(v, wnorm, u, scale_idx, &ScaleTable::new(scales), out)
}

/// Table-based form of [`multiscale_encode`].
pub fn multiscale_encode_t(
    v: &[f32],
    wnorm: f32,
    u: &[f32],
    scale_idx: &[u8],
    table: &ScaleTable,
    out: &mut [f32],
) {
    multiscale_encode_t_backend(simd::active(), v, wnorm, u, scale_idx, table, out)
}

/// Backend-explicit form of [`multiscale_encode_t`].
pub fn multiscale_encode_t_backend(
    bk: Backend,
    v: &[f32],
    wnorm: f32,
    u: &[f32],
    scale_idx: &[u8],
    table: &ScaleTable,
    out: &mut [f32],
) {
    if wnorm <= 0.0 {
        out.fill(0.0);
        return;
    }
    let done = simd::multiscale_levels(bk, v, wnorm, u, scale_idx, &table.sel, out);
    for i in done..v.len() {
        let s_eff = table.select(scale_idx[i] as u32);
        out[i] = qsgd_level(v[i], wnorm, u[i], s_eff);
    }
}

/// Integer-domain multi-scale encode (see [`qsgd_encode_int`]).
pub fn multiscale_encode_int<T: LevelInt>(
    v: &[f32],
    wnorm: f32,
    u: &[f32],
    scale_idx: &[u8],
    table: &ScaleTable,
    out: &mut [T],
) {
    multiscale_encode_int_backend(simd::active(), v, wnorm, u, scale_idx, table, out)
}

/// Backend-explicit form of [`multiscale_encode_int`] (stack-block level
/// materialization, same `T::from_level` funnel as
/// [`qsgd_encode_int_backend`]).
pub fn multiscale_encode_int_backend<T: LevelInt>(
    bk: Backend,
    v: &[f32],
    wnorm: f32,
    u: &[f32],
    scale_idx: &[u8],
    table: &ScaleTable,
    out: &mut [T],
) {
    debug_assert_eq!(v.len(), out.len());
    if wnorm <= 0.0 {
        out.fill(T::default());
        return;
    }
    let mut done = 0usize;
    if bk != Backend::Scalar {
        let mut block = [0.0f32; LEVEL_BLOCK];
        while done < v.len() {
            let take = (v.len() - done).min(LEVEL_BLOCK);
            let got = simd::multiscale_levels(
                bk,
                &v[done..done + take],
                wnorm,
                &u[done..done + take],
                &scale_idx[done..done + take],
                &table.sel,
                &mut block[..take],
            );
            if got == 0 {
                break;
            }
            for k in 0..got {
                out[done + k] = T::from_level(block[k]);
            }
            done += got;
            if got < take {
                break;
            }
        }
    }
    for i in done..v.len() {
        let s_eff = table.select(scale_idx[i] as u32);
        out[i] = T::from_level(qsgd_level(v[i], wnorm, u[i], s_eff));
    }
}

/// eq. (12) on the all-reduced sum: elementwise divide by s*, then /M.
/// Decode boundary: the scale-share indices crossed the wire, so the select
/// is range-checked — a poisoned share panics loudly instead of dividing by
/// the 0.0 padding lane and emitting silent ±inf gradients.
pub fn multiscale_decode_sum(
    zeta_sum: &mut [f32],
    wnorm: f32,
    scale_idx: &[u8],
    scales: &[usize],
    m: usize,
) {
    let table = ScaleTable::new(scales);
    let mf = m as f32;
    for (z, &idx) in zeta_sum.iter_mut().zip(scale_idx) {
        let s = table.select_checked(idx as u32);
        *z = *z * wnorm / (s * mf);
    }
}

/// eq. (12) from an integer level sum; float ops mirror
/// [`multiscale_decode_sum`] exactly.
pub fn multiscale_decode_sum_int<T: LevelInt>(
    sum: &[T],
    wnorm: f32,
    scale_idx: &[u8],
    table: &ScaleTable,
    m: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(sum.len(), out.len());
    debug_assert_eq!(sum.len(), scale_idx.len());
    let mf = m as f32;
    for i in 0..sum.len() {
        // decode boundary: wire-derived index, range-checked (satellite 2)
        let s = table.select_checked(scale_idx[i] as u32);
        out[i] = sum[i].to_f32() * wnorm / (s * mf);
    }
}

/// f32 L2 norm with f64 accumulation then rounding (matches the XLA
/// reduction within 1 ulp at gradient scales — see tensor::norm2_f32).
pub fn l2_norm(v: &[f32]) -> f32 {
    crate::tensor::norm2_f32(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{check, ensure, ensure_close};
    use crate::util::rng::Rng;

    #[test]
    fn bits_levels_mapping() {
        assert_eq!(s_for_bits(2), 1);
        assert_eq!(s_for_bits(4), 7);
        assert_eq!(s_for_bits(8), 127);
        assert_eq!(s_for_bits(12), 2047);
        assert_eq!(bits_for_s(1), 2.0); // levels {0,1} + sign
        assert_eq!(bits_for_s(127), 8.0);
        assert_eq!(bits_for_s(7), 4.0);
        assert_eq!(bits_for_s(2047), 12.0);
    }

    #[test]
    fn sign_matches_jnp() {
        assert_eq!(sign(3.0), 1.0);
        assert_eq!(sign(-3.0), -1.0);
        assert_eq!(sign(0.0), 0.0);
        assert_eq!(sign(-0.0), 0.0);
    }

    #[test]
    fn zero_vector_encodes_to_zero() {
        let v = vec![0.0f32; 16];
        let u = vec![0.5f32; 16];
        let mut out = vec![9.0f32; 16];
        qsgd_encode(&v, 0.0, &u, 7, &mut out);
        assert!(out.iter().all(|&z| z == 0.0));
    }

    #[test]
    fn prop_levels_bounded_and_integer() {
        check("qsgd levels in [-s, s] and integral", 200, |g| {
            let n = g.size_scaled(1, 4000);
            let s = *g.pick(&[1usize, 7, 31, 127, 2047]);
            let v = g.vec_normal(n, 1.5);
            let mut u = vec![0.0f32; n];
            g.rng().fill_uniform_f32(&mut u);
            let w = l2_norm(&v) * g.f32_in(1.0, 3.0); // >= ||v||
            let mut z = vec![0.0f32; n];
            qsgd_encode(&v, w, &u, s, &mut z);
            for (i, &zi) in z.iter().enumerate() {
                ensure(zi.fract() == 0.0, &format!("integral at {i}: {zi}"))?;
                ensure(zi.abs() <= s as f32, &format!("bounded at {i}: {zi} s={s}"))?;
                ensure(
                    sign(zi) == sign(v[i]) || zi == 0.0,
                    &format!("sign preserved at {i}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_unbiasedness_statistical() {
        // Lemma 5: E[Q_s(v)] = v. Monte-Carlo over the explicit u randomness.
        check("qsgd unbiased (statistical)", 10, |g| {
            let n = 64;
            let s = *g.pick(&[1usize, 7, 127]);
            let v = g.vec_normal(n, 1.0);
            let w = l2_norm(&v) * 1.5;
            let trials = 3000;
            let mut acc = vec![0.0f64; n];
            let mut rng = Rng::new(g.rng().next_u64());
            let mut u = vec![0.0f32; n];
            let mut z = vec![0.0f32; n];
            for _ in 0..trials {
                rng.fill_uniform_f32(&mut u);
                qsgd_encode(&v, w, &u, s, &mut z);
                let mut d = z.clone();
                qsgd_decode_sum(&mut d, w, s, 1);
                for i in 0..n {
                    acc[i] += d[i] as f64;
                }
            }
            // std error of the mean estimate per coord: w/(s*sqrt(trials))
            let se = 4.0 * w as f64 / (s as f64 * (trials as f64).sqrt());
            for i in 0..n {
                let mean = acc[i] / trials as f64;
                ensure_close(mean, v[i] as f64, (se / 1.0f64.max(v[i].abs() as f64)).max(1e-6), "E[Q(v)] = v")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_variance_bound_lemma5() {
        // Lemma 5: E||Q(v) - v||^2 <= min(n/s^2, sqrt(n)/s) * ||w||^2  (+ ||w||²-||v||² slack;
        // we check the tighter practical form E||Q(v)-v||² <= (1+min(...))||w||².
        check("qsgd variance bound (statistical)", 8, |g| {
            let n = 256;
            let s = *g.pick(&[1usize, 7, 31]);
            let v = g.vec_normal(n, 1.0);
            let w = l2_norm(&v) * g.f32_in(1.0, 2.0);
            let bound = {
                let nn = n as f64;
                let ss = s as f64;
                (1.0 + (nn / (ss * ss)).min(nn.sqrt() / ss)) * (w as f64) * (w as f64)
            };
            let trials = 500;
            let mut rng = Rng::new(g.rng().next_u64());
            let mut u = vec![0.0f32; n];
            let mut z = vec![0.0f32; n];
            let mut err_acc = 0.0f64;
            for _ in 0..trials {
                rng.fill_uniform_f32(&mut u);
                qsgd_encode(&v, w, &u, s, &mut z);
                let mut d = z.clone();
                qsgd_decode_sum(&mut d, w, s, 1);
                err_acc += d
                    .iter()
                    .zip(&v)
                    .map(|(a, b)| (*a as f64 - *b as f64).powi(2))
                    .sum::<f64>();
            }
            let mean_err = err_acc / trials as f64;
            ensure(
                mean_err <= bound * 1.1,
                &format!("variance {mean_err} exceeds Lemma 5 bound {bound} (s={s})"),
            )
        });
    }

    #[test]
    fn prop_multiscale_matches_min_scale_quantizer_when_single_scale() {
        check("multiscale with one scale == qsgd", 100, |g| {
            let n = g.size_scaled(1, 2000);
            let s = *g.pick(&[7usize, 127]);
            let v = g.vec_normal(n, 1.0);
            let mut u = vec![0.0f32; n];
            g.rng().fill_uniform_f32(&mut u);
            let w = l2_norm(&v) * 1.2;
            let scales = [s];
            let mut idx = vec![0u8; n];
            multiscale_scale_index(&v, w, &scales, &mut idx);
            let mut z_ms = vec![0.0f32; n];
            multiscale_encode(&v, w, &u, &idx, &scales, &mut z_ms);
            let mut z_q = vec![0.0f32; n];
            qsgd_encode(&v, w, &u, s, &mut z_q);
            ensure(z_ms == z_q, "single-scale multiscale must equal qsgd")
        });
    }

    #[test]
    fn prop_multiscale_levels_bounded_by_smin() {
        // eq. (10) guarantees a*s* <= smin, so levels <= smin + 1 — this is
        // exactly why the multi-scale wire format fits in the small-scale bits.
        check("multiscale level bound", 150, |g| {
            let n = g.size_scaled(1, 3000);
            let scale_sets: [&[usize]; 3] = [&[1, 31], &[7, 127], &[7, 31, 511]];
            let scales: &[usize] = scale_sets[g.usize_in(0, 2)];
            let v = g.vec_normal(n, 1.0);
            let mut u = vec![0.0f32; n];
            g.rng().fill_uniform_f32(&mut u);
            let w = l2_norm(&v) * g.f32_in(1.0, 2.0);
            let mut idx = vec![0u8; n];
            multiscale_scale_index(&v, w, scales, &mut idx);
            let mut z = vec![0.0f32; n];
            multiscale_encode(&v, w, &u, &idx, scales, &mut z);
            let smin = scales[0] as f32;
            for (i, &zi) in z.iter().enumerate() {
                ensure(
                    zi.abs() <= smin + 1.0,
                    &format!("level {zi} at {i} exceeds smin+1={}", smin + 1.0),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_multiscale_unbiased_statistical() {
        check("multiscale unbiased (statistical)", 6, |g| {
            let n = 64;
            let scales = [7usize, 127];
            let v = g.vec_normal(n, 1.0);
            let w = l2_norm(&v) * 1.5;
            let mut idx = vec![0u8; n];
            multiscale_scale_index(&v, w, &scales, &mut idx);
            let trials = 3000;
            let mut rng = Rng::new(g.rng().next_u64());
            let mut acc = vec![0.0f64; n];
            let mut u = vec![0.0f32; n];
            let mut z = vec![0.0f32; n];
            for _ in 0..trials {
                rng.fill_uniform_f32(&mut u);
                multiscale_encode(&v, w, &u, &idx, &scales, &mut z);
                let mut d = z.clone();
                multiscale_decode_sum(&mut d, w, &idx, &scales, 1);
                for i in 0..n {
                    acc[i] += d[i] as f64;
                }
            }
            let se = 4.0 * w as f64 / (7.0 * (trials as f64).sqrt());
            for i in 0..n {
                let mean = acc[i] / trials as f64;
                ensure_close(mean, v[i] as f64, (se / 1.0f64.max(v[i].abs() as f64)).max(1e-6), "E[Q_s(v)] = v")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_multiscale_variance_no_worse_than_single_scale() {
        // The multi-scale scheme's raison d'être: variance at equal wire
        // bits is <= the single-scale quantizer at the small scale.
        check("multiscale variance <= smin-scale variance", 6, |g| {
            let n = 512;
            let scales = [7usize, 127];
            let v = g.vec_normal(n, 1.0);
            let w = l2_norm(&v) * 1.2;
            let mut idx = vec![0u8; n];
            multiscale_scale_index(&v, w, &scales, &mut idx);
            let trials = 400;
            let mut rng = Rng::new(g.rng().next_u64());
            let (mut err_ms, mut err_ss) = (0.0f64, 0.0f64);
            let mut u = vec![0.0f32; n];
            let mut z = vec![0.0f32; n];
            for _ in 0..trials {
                rng.fill_uniform_f32(&mut u);
                multiscale_encode(&v, w, &u, &idx, &scales, &mut z);
                let mut d = z.clone();
                multiscale_decode_sum(&mut d, w, &idx, &scales, 1);
                err_ms += d.iter().zip(&v).map(|(a, b)| (*a as f64 - *b as f64).powi(2)).sum::<f64>();

                qsgd_encode(&v, w, &u, scales[0], &mut z);
                let mut d = z.clone();
                qsgd_decode_sum(&mut d, w, scales[0], 1);
                err_ss += d.iter().zip(&v).map(|(a, b)| (*a as f64 - *b as f64).powi(2)).sum::<f64>();
            }
            ensure(
                err_ms <= err_ss * 1.02,
                &format!("multiscale variance {err_ms} should be <= single-scale {err_ss}"),
            )
        });
    }

    #[test]
    fn scale_table_select_exhaustive_index_sweep() {
        // satellite 3: exhaustive 0..=MAX_SCALES sweep pins the padded
        // semantics of the unchecked select — in-range indices yield their
        // scale, every padding index yields exactly 0.0 (the hazard the
        // checked decode boundary exists to catch).
        for len in 1..=MAX_SCALES {
            let scales: Vec<usize> = (0..len).map(|i| (1usize << (i + 1)) - 1).collect();
            let table = ScaleTable::new(&scales);
            for idx in 0..=MAX_SCALES as u32 {
                let got = table.select(idx);
                if (idx as usize) < len {
                    assert_eq!(got, scales[idx as usize] as f32, "len={len} idx={idx}");
                    assert_eq!(table.select_checked(idx), got);
                } else {
                    assert_eq!(got, 0.0, "padding lane must select 0.0 (len={len} idx={idx})");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "scale index")]
    fn poisoned_scale_share_cannot_reach_decode() {
        // satellite 2 regression (fails pre-fix): a corrupted scale-share
        // byte >= the table length used to select the 0.0 padding lane and
        // decode to ±inf with no signal. It must panic at the decode
        // boundary instead — in release builds too.
        let table = ScaleTable::new(&[7, 127]);
        let sum = vec![5i32; 4];
        let idx = vec![0u8, 1, 7, 0]; // idx 7 is poisoned (table len 2)
        let mut out = vec![0.0f32; 4];
        multiscale_decode_sum_int(&sum, 1.0, &idx, &table, 2, &mut out);
    }

    #[test]
    #[should_panic(expected = "scale index")]
    fn poisoned_scale_share_cannot_reach_f32_decode() {
        let mut sum = vec![5.0f32; 3];
        let idx = vec![0u8, 200, 1]; // 200 is far out of range
        multiscale_decode_sum(&mut sum, 1.0, &idx, &[7, 127], 2);
    }

    #[test]
    fn decode_output_stays_finite_with_valid_shares() {
        // companion to the poisoned-share test: the checked boundary is
        // transparent for every legal index.
        let table = ScaleTable::new(&[7, 127]);
        let sum = vec![3i32, -14, 0, 7];
        let idx = vec![0u8, 1, 0, 1];
        let mut out = vec![0.0f32; 4];
        multiscale_decode_sum_int(&sum, 2.0, &idx, &table, 2, &mut out);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn backend_encode_paths_bit_identical_to_scalar() {
        // the tentpole contract at the kernels layer: every available SIMD
        // backend produces bit-identical levels / indices to the scalar
        // reference, across lengths that exercise block seams and tails,
        // with adversarial inputs (±0.0, denormals, u == p boundaries).
        check("simd kernels == scalar", 60, |g| {
            let n = g.size_scaled(1, 1200);
            let mut v = g.vec_adversarial(n);
            // sprinkle signed zeros and denormals
            for k in (0..n).step_by(9) {
                v[k] = if g.bool() { -0.0 } else { 1e-42 };
            }
            let mut u = vec![0.0f32; n];
            g.rng().fill_uniform_f32(&mut u);
            let w = crate::tensor::norm2_f32(&v).max(1e-30) * g.f32_in(1.0, 2.0);
            let s = *g.pick(&[1usize, 7, 127, 2047]);
            // u == p rounding boundary at a few coords
            for k in (0..n).step_by(7) {
                let scaled = v[k].abs() / w * s as f32;
                u[k] = scaled - scaled.floor();
            }
            let table = ScaleTable::new(&[7, 127, 2047]);
            let mut idx_ref = vec![0u8; n];
            multiscale_scale_index_t_backend(simd::Backend::Scalar, &v, w, &table, &mut idx_ref);

            let mut z_ref = vec![0.0f32; n];
            qsgd_encode_backend(simd::Backend::Scalar, &v, w, &u, s, &mut z_ref);
            let mut zi_ref = vec![0i32; n];
            qsgd_encode_int_backend(simd::Backend::Scalar, &v, w, &u, s, &mut zi_ref);
            let mut ms_ref = vec![0i16; n];
            multiscale_encode_int_backend(simd::Backend::Scalar, &v, w, &u, &idx_ref, &table, &mut ms_ref);

            for bk in simd::available() {
                let mut idx = vec![0u8; n];
                multiscale_scale_index_t_backend(bk, &v, w, &table, &mut idx);
                ensure(idx == idx_ref, &format!("{bk:?} scale index diverged"))?;
                let mut z = vec![0.0f32; n];
                qsgd_encode_backend(bk, &v, w, &u, s, &mut z);
                for i in 0..n {
                    ensure(
                        z[i].to_bits() == z_ref[i].to_bits(),
                        &format!("{bk:?} qsgd f32 level bits diverged at {i}"),
                    )?;
                }
                let mut zi = vec![0i32; n];
                qsgd_encode_int_backend(bk, &v, w, &u, s, &mut zi);
                ensure(zi == zi_ref, &format!("{bk:?} qsgd int level diverged"))?;
                let mut ms = vec![0i16; n];
                multiscale_encode_int_backend(bk, &v, w, &u, &idx, &table, &mut ms);
                ensure(ms == ms_ref, &format!("{bk:?} multiscale int level diverged"))?;
            }
            Ok(())
        });
    }
}
