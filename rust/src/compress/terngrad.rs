//! TernGrad baseline (Wen et al. 2017): unbiased ternary quantization.
//!
//! Levels {-1, 0, +1} scaled by the gradient's max magnitude:
//! `q_i = s_t · sign(v_i) · b_i` with `b_i ~ Bernoulli(|v_i| / s_t)`.
//! The original shares the per-worker scaler via "scaler sharing" (max over
//! workers) to allow parameter-server summation — the exact analogue of the
//! paper's MaxNorm trick, so our implementation max-all-reduces
//! `s_t = max_m max_i |v_i^m|` and aggregates ternary levels with a single
//! sum all-reduce at 2 bits/coordinate.

use crate::collectives::StepCtx;
use crate::util::rng::Rng;

use super::kernels::sign;
use super::Aggregator;

pub struct TernGrad {
    scratch: Vec<Vec<f32>>,
}

impl TernGrad {
    pub fn new() -> TernGrad {
        TernGrad { scratch: Vec::new() }
    }
}

impl Default for TernGrad {
    fn default() -> Self {
        Self::new()
    }
}

impl Aggregator for TernGrad {
    fn name(&self) -> String {
        "TernGrad".into()
    }

    fn allreduce_compatible(&self) -> bool {
        true
    }

    fn nominal_bits(&self) -> f64 {
        2.0
    }

    fn aggregate(&mut self, grads: &[&[f32]], ctx: &mut StepCtx, rng: &mut Rng) -> Vec<f32> {
        let m = grads.len();
        let n = grads[0].len();

        // scaler sharing: global max magnitude
        let local_max: Vec<f32> = grads.iter().map(|g| crate::tensor::norm_inf(g)).collect();
        let st = ctx.allreduce_max_scalar(&local_max);

        self.scratch.resize_with(m, Vec::new);
        let scratch = &mut self.scratch;
        ctx.time_encode(|| {
            for (w, g) in grads.iter().enumerate() {
                let mut wrng = rng.derive(&[w as u64]);
                scratch[w].resize(n, 0.0);
                if st <= 0.0 {
                    scratch[w].fill(0.0);
                    continue;
                }
                for (o, &v) in scratch[w].iter_mut().zip(g.iter()) {
                    let p = v.abs() / st;
                    let b = if wrng.next_f32() < p { 1.0 } else { 0.0 };
                    *o = sign(v) * b;
                }
            }
        });

        let bufs: Vec<Vec<f32>> = scratch.iter().map(|v| v.clone()).collect();
        let mut sum = ctx.allreduce_sum(bufs, 2.0);
        ctx.time_decode(|| crate::tensor::scale(st / m as f32, &mut sum));
        sum
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{NetConfig, SimClock};
    use crate::util::quickcheck::{check, ensure, ensure_close};

    fn run(grads: &[Vec<f32>], seed: u64) -> (Vec<f32>, f64) {
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let net = NetConfig::flat(grads.len(), 10.0);
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut clock);
        let mut rng = Rng::new(seed);
        let out = TernGrad::new().aggregate(&refs, &mut ctx, &mut rng);
        (out, clock.bits_per_worker)
    }

    #[test]
    fn prop_output_is_ternary_scaled() {
        check("terngrad levels in {-st,0,st}/M scale", 60, |g| {
            let m = g.usize_in(1, 5);
            let n = g.size_scaled(1, 1000);
            let grads: Vec<Vec<f32>> = (0..m).map(|_| g.vec_normal(n, 1.0)).collect();
            let st = grads
                .iter()
                .map(|v| crate::tensor::norm_inf(v))
                .fold(0.0f32, f32::max);
            let (out, _) = run(&grads, g.rng().next_u64());
            let unit = st / m as f32;
            for (i, &o) in out.iter().enumerate() {
                let k = o / unit;
                ensure(
                    (k.round() - k).abs() < 1e-4 && k.abs() <= m as f32 + 0.01,
                    &format!("idx {i}: {o} not a ternary sum multiple (unit {unit})"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_unbiased_statistical() {
        check("terngrad unbiased", 4, |g| {
            let n = 64;
            let grads: Vec<Vec<f32>> = (0..2).map(|_| g.vec_normal(n, 1.0)).collect();
            let mean =
                crate::tensor::mean_of(&grads.iter().map(|v| v.as_slice()).collect::<Vec<_>>());
            let trials = 4000;
            let mut acc = vec![0.0f64; n];
            for t in 0..trials {
                let (out, _) = run(&grads, 31337 + t as u64);
                for i in 0..n {
                    acc[i] += out[i] as f64;
                }
            }
            let st = grads.iter().map(|v| crate::tensor::norm_inf(v)).fold(0.0f32, f32::max) as f64;
            let se = 4.0 * st / (trials as f64).sqrt();
            for i in 0..n {
                ensure_close(
                    acc[i] / trials as f64,
                    mean[i] as f64,
                    (se / 1.0f64.max(mean[i].abs() as f64)).max(1e-6),
                    "unbiased",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn wire_is_two_bits() {
        let grads: Vec<Vec<f32>> = (0..4).map(|_| vec![0.5f32; 100]).collect();
        let (_, bits) = run(&grads, 1);
        assert_eq!(bits, 32.0 + 200.0);
    }

    #[test]
    fn zero_grads_zero_output() {
        let grads: Vec<Vec<f32>> = (0..3).map(|_| vec![0.0f32; 10]).collect();
        let (out, _) = run(&grads, 2);
        assert!(out.iter().all(|&x| x == 0.0));
    }
}
