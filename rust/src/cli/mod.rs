//! Hand-rolled CLI argument parser (no clap in the vendored set).
//!
//! Grammar: `repro <subcommand> [--key value]... [--flag]...`
//! Values may also be given as `--key=value`. Unknown keys are an error —
//! typos in experiment scripts should fail loudly, not silently fall back
//! to defaults.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed arguments: the subcommand plus key/value options.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    /// keys the program looked up — for unknown-key detection
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();

        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                args.subcommand = Some(it.next().unwrap());
            }
        }
        while let Some(tok) = it.next() {
            let key = tok
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --option, got '{tok}'"))?;
            if let Some((k, v)) = key.split_once('=') {
                args.opts.insert(k.to_string(), v.to_string());
            } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                args.opts.insert(key.to_string(), it.next().unwrap());
            } else {
                args.flags.push(key.to_string());
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.seen.borrow_mut().push(name.to_string());
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.seen.borrow_mut().push(name.to_string());
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name}={v}: {e}")),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow!("missing required --{name}"))
    }

    /// Comma-separated list, e.g. `--bits 2,4,8`.
    pub fn parse_list<T: std::str::FromStr>(&self, name: &str, default: &[T]) -> Result<Vec<T>>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| p.trim().parse().map_err(|e| anyhow!("--{name} item '{p}': {e}")))
                .collect(),
        }
    }

    /// Call after all lookups: errors on any option the program never read.
    pub fn reject_unknown(&self) -> Result<()> {
        let seen = self.seen.borrow();
        for k in self.opts.keys() {
            if !seen.iter().any(|s| s == k) {
                bail!("unknown option --{k}");
            }
        }
        for f in &self.flags {
            if !seen.iter().any(|s| s == f) {
                bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train --model mlp --steps 100 --verbose --lr=0.5");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("model"), Some("mlp"));
        assert_eq!(a.parse_or("steps", 0usize).unwrap(), 100);
        assert_eq!(a.parse_or("lr", 0.0f64).unwrap(), 0.5);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn lists_and_defaults() {
        let a = parse("x --bits 2,4,8");
        assert_eq!(a.parse_list("bits", &[0usize]).unwrap(), vec![2, 4, 8]);
        assert_eq!(a.parse_list("other", &[7usize]).unwrap(), vec![7]);
        assert_eq!(a.get_or("model", "mlp"), "mlp");
    }

    #[test]
    fn unknown_detection() {
        let a = parse("t --known 1 --typo 2");
        let _ = a.get("known");
        assert!(a.reject_unknown().is_err());
        let _ = a.get("typo");
        assert!(a.reject_unknown().is_ok());
    }

    #[test]
    fn bad_parse_is_error() {
        let a = parse("t --steps abc");
        assert!(a.parse_or("steps", 0usize).is_err());
        assert!(a.require("nope").is_err());
    }
}
